"""Schedule-construction scaling: reference vs vectorized paths.

For n in {8, 32, 128, 512, 1024} kernels, on two workload mixes
(GTX580 kernel soup; TPU serving prefill+decode items), measures

* wall time of schedule construction — greedy + default-budget refine
  (200 evaluations, the serving default) — for the pure-Python
  reference path (the test-only oracle) vs the vectorized/incremental
  fast path, and
* the modelled execution time of the produced order under both the
  round model (the refine objective) and the event simulator,

plus a **DAG-constrained construction** section (the ready-set greedy
``repro.graph.greedy_order_dag`` over chain-structured random DAGs,
path ``dag_fast`` — guarded by ``check_regression.py`` alongside the
flat fast path), and a second section for **event-model refinement** at n in
{64, 128, 256, 512, 1024}: full re-simulation per candidate (the
reference ``EventSimulator``, the pre-checkpointing status quo) vs
the checkpointing delta path (``refine_order(model="event")``, suffix
re-simulation via ``DeltaEvaluator``), reporting effective-move
throughput (candidate moves evaluated per second) for both.  The
acceptance bar is >= 5x delta throughput at n = 256.

A **gated-DAG refinement** section (ISSUE 5) measures
``refine_order_dag(model="gated")`` over the same chain-structured
DAGs: the checkpointing gated delta path
(``repro.graph.delta.GatedDeltaEvaluator``, path ``dag_refine_gated``
— guarded by ``check_regression.py``) vs full gated re-simulation per
candidate (``DagEventSimulator`` as ``time_fn``, path
``dag_refine_gated_full``, skipped above ``--max-gated-full-n``).

**Batched refinement** sections (ISSUE 6) measure the vectorized
candidate evaluator (``repro.core.batched.refine_order_batched``
behind the ``batch_size=`` knob): path ``event_batched`` over n in
{256 .. 4096} against the sequential ``event_delta`` cells at the
shared ns (the ISSUE-6 bar is >= 3x effective-move throughput at
n >= 512), path ``dag_refine_gated_batched`` over the gated band,
and an ``arch_gated_quality`` pin — batched gated refinement is
never worse than sequential on the three traced-arch workloads
(4-core serving slice).

Emits ``BENCH_scheduler_scaling.json`` for the perf trajectory
(consumed by ``benchmarks/check_regression.py``).  The reference
construction path is O(R * n^2) Python-level ScoreGen reruns and is
skipped above ``--max-ref-n`` (default 512, ~35 s there); pass
``--full`` to run it everywhere.  The full-re-sim event-refine path
is skipped above ``--max-event-full-n`` (default 256).

Run:  PYTHONPATH=src python benchmarks/scaling.py
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.core import (GTX580, EventSimulator, RoundSimulator,
                        greedy_order, greedy_order_fast, simulate)
from repro.core.refine import refine_order
from repro.core.resources import (KernelProfile, bs_kernel, ep_kernel,
                                  es_kernel, sw_kernel)
from repro.core.tpu import decode_profile, make_serving_device, prefill_profile
from repro.graph import (DagEventSimulator, KernelGraph, greedy_order_dag,
                         refine_order_dag)
from repro.slice import SlicePolicy, greedy_order_slices

REFINE_BUDGET = 200
NS = (8, 32, 128, 512, 1024)
#: event-model refine: budget in full-simulation equivalents, and the
#: ns it is measured at (the serving-relevant 64..1024 band).  Kept
#: deliberately small: event re-simulation is the expensive objective,
#: and a serving deployment would spend far less on it than the
#: round-model default of 200.
EVENT_BUDGET = 40
EVENT_NS = (64, 128, 256, 512, 1024)
#: gated-DAG refine (ISSUE 5): same budget discipline, smaller band —
#: each gated full sim walks the whole dependency frontier, so the
#: full-re-sim baseline is capped separately (--max-gated-full-n).
GATED_NS = (64, 128, 256, 512)
#: batched refine (ISSUE 6): the vectorized candidate evaluator
#: (``repro.core.batched.refine_order_batched`` behind the
#: ``batch_size=`` knob) scores whole ``(B, n)`` move batches per
#: pass; measured against the sequential delta path at the shared ns
#: and batched-only at the 2048/4096 scaling cells (where sequential
#: evaluation is no longer a reasonable baseline to wait for).
BATCH_SIZE = 512
BATCHED_NS = (256, 512, 1024, 2048, 4096)
#: traced archs for the batched-gated quality pin (same workloads as
#: benchmarks/dag.py, on the 4-core serving slice where the gated
#: makespan is genuinely order-sensitive)
ARCHS = ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b")
_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]


def gpu_mix(rng: random.Random, n: int) -> list[KernelProfile]:
    return [rng.choice(_FAMS)(f"k{i}",
                              grid=rng.choice([8, 16, 32, 48, 64, 96]),
                              shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                              inst=rng.uniform(1e6, 5e8))
            for i in range(n)]


def tpu_mix(rng: random.Random, n: int) -> list[KernelProfile]:
    out = []
    for i in range(n):
        if rng.random() < 0.3:
            it = prefill_profile(f"p{i}", n_params=7e9,
                                 seq_len=rng.choice([128, 256, 512, 1024]),
                                 kv_bytes_per_token=131072)
        else:
            it = decode_profile(f"d{i}", n_params=7e9,
                                kv_len=rng.randint(64, 8192),
                                kv_bytes_per_token=131072)
        out.append(it.profile())
    return out


SCENARIOS = (
    ("gpu_mix", GTX580, gpu_mix),
    ("tpu_serving", make_serving_device(), tpu_mix),
)


def _best_of(repeats: int, fn):
    """Run ``fn`` ``repeats`` times; keep the record with the smallest
    wall time.  Construction is deterministic, so min-of-k only strips
    scheduler/host noise from the timing — the standard protocol for
    wall-clock guards (``check_regression.py`` compares min against
    min)."""
    best = None
    for _ in range(max(repeats, 1)):
        rec = fn()
        if best is None or rec["wall_s"] < best["wall_s"]:
            best = rec
    return best


def construct(ks, device, path: str) -> dict:
    """Greedy + default-budget refine; returns wall time + quality."""
    t0 = time.perf_counter()
    if path == "reference":
        sched = greedy_order(ks, device)
        sim = RoundSimulator(device)
        order, t_round, evals = refine_order(
            sched.order, device, time_fn=sim.simulate,
            budget=REFINE_BUDGET)
    else:
        sched = greedy_order_fast(ks, device)
        order, t_round, evals = refine_order(
            sched.order, device, model="round", budget=REFINE_BUDGET,
            neighborhood="auto")
    wall = time.perf_counter() - t0
    return {
        "path": path,
        "wall_s": wall,
        "rounds": len(sched.rounds),
        "refine_evals": evals,
        "modelled_round_time_s": t_round,
        "modelled_event_time_s": simulate(order, device),
    }


def chain_edges(rng: random.Random, n: int,
                width: int) -> set[tuple[int, int]]:
    """``width`` parallel chains over ``n`` kernels (the traced-arch
    edge shape: intra-request chains, cross-request independence)."""
    edges: set[tuple[int, int]] = set()
    chains: list[list[int]] = [[] for _ in range(max(width, 1))]
    for i in range(n):
        c = chains[rng.randrange(len(chains))]
        if c:
            edges.add((c[-1], i))
        c.append(i)
    return edges


def dag_construct(ks, edges, device) -> dict:
    """Ready-set greedy construction over a kernel DAG; wall time is
    the guarded quantity (``check_regression.py``, path="dag_fast")."""
    t0 = time.perf_counter()
    sched = greedy_order_dag(ks, device, edges=edges)
    wall = time.perf_counter() - t0
    assert KernelGraph(ks, edges).is_topological(sched.order)
    return {"path": "dag_fast", "wall_s": wall,
            "rounds": len(sched.rounds), "n_edges": len(edges)}


def slice_mix(rng: random.Random, n: int) -> list[KernelProfile]:
    """TPU serving mix with ~12% oversized prefill stages (tokens
    above the 4096-slot round budget) — the workload shape the lazy
    slice greedy exists for."""
    out = []
    for i in range(n):
        u = rng.random()
        if u < 0.12:
            it = prefill_profile(f"P{i}", n_params=7e9,
                                 seq_len=rng.choice([6144, 8192, 12288]),
                                 kv_bytes_per_token=131072)
        elif u < 0.3:
            it = prefill_profile(f"p{i}", n_params=7e9,
                                 seq_len=rng.choice([128, 256, 512, 1024]),
                                 kv_bytes_per_token=131072)
        else:
            it = decode_profile(f"d{i}", n_params=7e9,
                                kv_len=rng.randint(64, 8192),
                                kv_bytes_per_token=131072)
        out.append(it.profile())
    return out


def slice_construct(ks, edges, device) -> dict:
    """Lazy slice-aware greedy construction
    (``repro.slice.greedy_order_slices``); wall time is the guarded
    quantity (``check_regression.py``, path="slice_fast")."""
    t0 = time.perf_counter()
    res = greedy_order_slices(ks, device, edges=edges,
                              policy=SlicePolicy())
    wall = time.perf_counter() - t0
    assert res.graph().is_topological(res.order)
    return {"path": "slice_fast", "wall_s": wall,
            "rounds": len(res.rounds), "n_edges": len(res.edges),
            "n_sliced": len(res.sliced),
            "n_expanded": len(res.kernels)}


def gated_refine(ks, edges, device, path: str) -> dict:
    """Gated-model local search on the constrained greedy order:
    checkpointing delta path (``dag_refine_gated`` — the guarded
    cell) vs full gated re-simulation per candidate
    (``dag_refine_gated_full``)."""
    g = KernelGraph(ks, edges)
    eids = g.edges_by_id()
    order = greedy_order_dag(ks, device, edges=edges).order
    t0 = time.perf_counter()
    if path == "dag_refine_gated_full":
        sim = DagEventSimulator(device, eids)
        _, t_g, evals = refine_order_dag(
            order, device, edge_ids=eids, time_fn=sim.simulate,
            budget=EVENT_BUDGET, neighborhood="adjacent")
    elif path == "dag_refine_gated_batched":
        # rescore=False: this is the *throughput* cell, measured under
        # the fast contract (quality pinned to the input order).  The
        # arch_gated_quality cells run the default sequential-parity
        # contract (rescore on), which trades engine passes for
        # matching the sequential refiner's makespans.
        _, t_g, evals = refine_order_dag(
            order, device, edge_ids=eids, model="gated",
            budget=EVENT_BUDGET, neighborhood="adjacent",
            batch_size=BATCH_SIZE, rescore=False)
    else:
        _, t_g, evals = refine_order_dag(
            order, device, edge_ids=eids, model="gated",
            budget=EVENT_BUDGET, neighborhood="adjacent")
    wall = time.perf_counter() - t0
    return {"path": path, "wall_s": wall, "refine_evals": evals,
            "moves_per_s": evals / max(wall, 1e-9),
            "modelled_gated_time_s": t_g, "n_edges": len(edges)}


def event_refine(ks, device, path: str) -> dict:
    """Event-model local search on the greedy order; returns wall time,
    evaluated moves and effective-move throughput."""
    order = greedy_order_fast(ks, device).order
    t0 = time.perf_counter()
    if path == "event_full":
        sim = EventSimulator(device)
        _, t_ev, evals = refine_order(
            order, device, time_fn=sim.simulate,
            budget=EVENT_BUDGET, neighborhood="adjacent")
    elif path == "event_batched":
        _, t_ev, evals = refine_order(
            order, device, model="event", budget=EVENT_BUDGET,
            neighborhood="adjacent", batch_size=BATCH_SIZE)
    else:
        _, t_ev, evals = refine_order(
            order, device, model="event", budget=EVENT_BUDGET,
            neighborhood="adjacent")
    wall = time.perf_counter() - t0
    return {"path": path, "wall_s": wall, "refine_evals": evals,
            "moves_per_s": evals / max(wall, 1e-9),
            "modelled_event_time_s": t_ev}


def arch_gated_quality(arch: str) -> dict:
    """Batched-vs-sequential gated refinement on a traced arch (the
    4-core serving slice, where the gated makespan is genuinely
    order-sensitive): the batched path's exact re-verification before
    acceptance pins its refined makespan to never-worse than its
    input, and this cell pins it against the *sequential* refiner's
    result on real workloads (the ISSUE-6 quality bar)."""
    from repro.configs import get_config
    from repro.graph import greedy_order_dag, trace_arch

    dev4 = make_serving_device(n_units=4)
    traced = trace_arch(get_config(arch, "full"), max_stages=16)
    g = traced.graph
    eids = g.edges_by_id()
    order = greedy_order_dag(g.kernels, dev4, edges=g.edges).order
    t0 = time.perf_counter()
    _, t_seq, _ = refine_order_dag(
        order, dev4, edge_ids=eids, model="gated",
        budget=EVENT_BUDGET, neighborhood="adjacent")
    wall_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    _, t_bat, _ = refine_order_dag(
        order, dev4, edge_ids=eids, model="gated",
        budget=EVENT_BUDGET, neighborhood="adjacent",
        batch_size=BATCH_SIZE)
    wall_bat = time.perf_counter() - t0
    return {"path": "arch_gated_quality", "n": len(g.kernels),
            "wall_s": wall_bat, "wall_seq_s": wall_seq,
            "gated_time_sequential_s": t_seq,
            "gated_time_batched_s": t_bat,
            "batched_no_worse": t_bat <= t_seq * (1 + 1e-9)}


def run(max_ref_n: int = 512, seed: int = 0, max_event_full_n: int = 256,
        max_gated_full_n: int = 128, repeats: int = 2,
        max_batched_n: int = 1024, arch_quality: bool = False,
        print_fn=print) -> dict:
    results = []
    print_fn("# Scheduler scaling: reference vs vectorized "
             f"(refine budget {REFINE_BUDGET}, best of {repeats})")
    print_fn("scenario,n,path,wall_s,round_time_s,event_time_s,speedup")
    for name, device, maker in SCENARIOS:
        for n in NS:
            rng = random.Random(seed)
            ks = maker(rng, n)
            fast = _best_of(repeats,
                            lambda: construct(ks, device, "fast"))
            ref = None
            if n <= max_ref_n:
                # Same best-of-k protocol as the fast cell: asymmetric
                # sampling would systematically inflate the speedups.
                ref = _best_of(repeats,
                               lambda: construct(ks, device, "reference"))
            for rec in filter(None, (ref, fast)):
                speedup = (ref["wall_s"] / fast["wall_s"]
                           if ref is not None and rec is fast else "")
                print_fn(f"{name},{n},{rec['path']},"
                         f"{rec['wall_s']:.4f},"
                         f"{rec['modelled_round_time_s']:.5f},"
                         f"{rec['modelled_event_time_s']:.5f},"
                         f"{speedup if speedup == '' else f'{speedup:.1f}'}")
                results.append({"scenario": name, "n": n, **rec})
    print_fn("# DAG-constrained construction (ready-set greedy, "
             f"chain-structured edges, best of {repeats})")
    print_fn("scenario,n,path,wall_s,rounds,n_edges")
    for n in NS:
        rng = random.Random(seed)
        ks = gpu_mix(rng, n)
        edges = chain_edges(rng, n, width=max(4, n // 8))
        rec = _best_of(repeats,
                       lambda: dag_construct(ks, edges, GTX580))
        print_fn(f"gpu_dag,{n},{rec['path']},{rec['wall_s']:.4f},"
                 f"{rec['rounds']},{rec['n_edges']}")
        results.append({"scenario": "gpu_dag", "n": n, **rec})
    print_fn("# Sliced-DAG construction (lazy slice greedy, oversized "
             f"TPU serving mix, best of {repeats})")
    print_fn("scenario,n,path,wall_s,rounds,n_sliced,n_expanded")
    tpu_dev = make_serving_device()
    for n in NS:
        rng = random.Random(seed)
        ks = slice_mix(rng, n)
        edges = chain_edges(rng, n, width=max(4, n // 8))
        rec = _best_of(repeats,
                       lambda: slice_construct(ks, edges, tpu_dev))
        print_fn(f"tpu_slice,{n},{rec['path']},{rec['wall_s']:.4f},"
                 f"{rec['rounds']},{rec['n_sliced']},{rec['n_expanded']}")
        results.append({"scenario": "tpu_slice", "n": n, **rec})
    print_fn("# Event-model refine: full re-sim vs checkpoint delta "
             f"(budget {EVENT_BUDGET} full-sim equivalents)")
    print_fn("scenario,n,path,wall_s,evals,moves_per_s,throughput_ratio")
    for n in EVENT_NS:
        rng = random.Random(seed)
        ks = gpu_mix(rng, n)
        delta = _best_of(repeats,
                         lambda: event_refine(ks, GTX580, "event_delta"))
        full = None
        if n <= max_event_full_n:
            full = _best_of(repeats,
                            lambda: event_refine(ks, GTX580, "event_full"))
        for rec in filter(None, (full, delta)):
            ratio = (rec["moves_per_s"] / full["moves_per_s"]
                     if full is not None and rec is delta else "")
            print_fn(f"gpu_mix,{n},{rec['path']},{rec['wall_s']:.4f},"
                     f"{rec['refine_evals']},{rec['moves_per_s']:.1f},"
                     f"{ratio if ratio == '' else f'{ratio:.1f}'}")
            results.append({"scenario": "gpu_mix", "n": n, **rec})
    print_fn("# Gated-DAG refine: full re-sim vs checkpoint delta "
             f"(budget {EVENT_BUDGET} full-sim equivalents, "
             "chain-structured edges)")
    print_fn("scenario,n,path,wall_s,evals,moves_per_s,throughput_ratio")
    for n in GATED_NS:
        rng = random.Random(seed)
        ks = gpu_mix(rng, n)
        edges = chain_edges(rng, n, width=max(4, n // 8))
        delta = _best_of(repeats, lambda: gated_refine(
            ks, edges, GTX580, "dag_refine_gated"))
        full = None
        if n <= max_gated_full_n:
            full = _best_of(repeats, lambda: gated_refine(
                ks, edges, GTX580, "dag_refine_gated_full"))
        for rec in filter(None, (full, delta)):
            ratio = (rec["moves_per_s"] / full["moves_per_s"]
                     if full is not None and rec is delta else "")
            print_fn(f"gpu_dag,{n},{rec['path']},{rec['wall_s']:.4f},"
                     f"{rec['refine_evals']},{rec['moves_per_s']:.1f},"
                     f"{ratio if ratio == '' else f'{ratio:.1f}'}")
            results.append({"scenario": "gpu_dag", "n": n, **rec})
    print_fn("# Batched event refine (ISSUE 6): vectorized (B, n) "
             f"candidate batches, batch_size {BATCH_SIZE}; throughput "
             "ratio vs the sequential event_delta cell at the same n")
    print_fn("scenario,n,path,wall_s,evals,moves_per_s,"
             "throughput_ratio_vs_delta")
    delta_tp = {r["n"]: r["moves_per_s"] for r in results
                if r["path"] == "event_delta"}
    for n in BATCHED_NS:
        if n > max_batched_n:
            continue
        rng = random.Random(seed)
        ks = gpu_mix(rng, n)
        rec = _best_of(repeats,
                       lambda: event_refine(ks, GTX580, "event_batched"))
        ratio = (rec["moves_per_s"] / delta_tp[n]
                 if n in delta_tp else "")
        print_fn(f"gpu_mix,{n},{rec['path']},{rec['wall_s']:.4f},"
                 f"{rec['refine_evals']},{rec['moves_per_s']:.1f},"
                 f"{ratio if ratio == '' else f'{ratio:.2f}'}")
        results.append({"scenario": "gpu_mix", "n": n, **rec})
    print_fn("# Batched gated refine: same chain DAGs as the gated "
             "delta section")
    print_fn("scenario,n,path,wall_s,evals,moves_per_s")
    for n in GATED_NS:
        if n > max_batched_n:
            continue
        rng = random.Random(seed)
        ks = gpu_mix(rng, n)
        edges = chain_edges(rng, n, width=max(4, n // 8))
        rec = _best_of(repeats, lambda: gated_refine(
            ks, edges, GTX580, "dag_refine_gated_batched"))
        print_fn(f"gpu_dag,{n},{rec['path']},{rec['wall_s']:.4f},"
                 f"{rec['refine_evals']},{rec['moves_per_s']:.1f}")
        results.append({"scenario": "gpu_dag", "n": n, **rec})
    if arch_quality:
        print_fn("# Batched gated quality pin on traced archs "
                 "(4-core serving slice): batched <= sequential")
        print_fn("workload,n,gated_seq_ms,gated_batched_ms,no_worse")
        for arch in ARCHS:
            rec = arch_gated_quality(arch)
            print_fn(f"arch:{arch},{rec['n']},"
                     f"{rec['gated_time_sequential_s'] * 1e3:.3f},"
                     f"{rec['gated_time_batched_s'] * 1e3:.3f},"
                     f"{rec['batched_no_worse']}")
            results.append({"scenario": f"arch:{arch}", **rec})
    summary = _summary(results)
    out = {"benchmark": "scheduler_scaling",
           "refine_budget": REFINE_BUDGET,
           "event_refine_budget": EVENT_BUDGET,
           "ns": list(NS), "event_ns": list(EVENT_NS),
           "gated_ns": list(GATED_NS),
           "batched_ns": list(BATCHED_NS),
           "batch_size": BATCH_SIZE,
           "max_ref_n": max_ref_n,
           "max_event_full_n": max_event_full_n,
           "max_gated_full_n": max_gated_full_n,
           "max_batched_n": max_batched_n,
           "repeats": repeats,
           "results": results, "summary": summary}
    print_fn(f"summary: {json.dumps(summary)}")
    return out


def _summary(results: list[dict]) -> dict:
    by = {(r["scenario"], r["n"], r["path"]): r for r in results}
    speedups = {}
    quality_ok = True
    for (scen, n, path), r in by.items():
        if path != "reference":
            continue
        f = by.get((scen, n, "fast"))
        if f is None:
            continue
        speedups[f"{scen}@n={n}"] = r["wall_s"] / f["wall_s"]
        if f["modelled_round_time_s"] > r["modelled_round_time_s"] * (1 + 1e-9):
            quality_ok = False
    s512 = {k: v for k, v in speedups.items() if k.endswith("n=512")}
    event_tp = {}
    for (scen, n, path), r in by.items():
        if path != "event_full":
            continue
        d = by.get((scen, n, "event_delta"))
        if d is not None:
            event_tp[f"{scen}@n={n}"] = (d["moves_per_s"] /
                                         max(r["moves_per_s"], 1e-9))
    tp256 = [v for k, v in event_tp.items() if k.endswith("n=256")]
    gated_tp = {}
    for (scen, n, path), r in by.items():
        if path != "dag_refine_gated_full":
            continue
        d = by.get((scen, n, "dag_refine_gated"))
        if d is not None:
            gated_tp[f"{scen}@n={n}"] = (d["moves_per_s"] /
                                         max(r["moves_per_s"], 1e-9))
    batched_tp = {}
    for (scen, n, path), r in by.items():
        if path != "event_delta":
            continue
        b = by.get((scen, n, "event_batched"))
        if b is not None:
            batched_tp[f"{scen}@n={n}"] = (b["moves_per_s"] /
                                           max(r["moves_per_s"], 1e-9))
    tp512plus = [v for k, v in batched_tp.items()
                 if int(k.rsplit("n=", 1)[1]) >= 512]
    batched_gated_tp = {}
    for (scen, n, path), r in by.items():
        if path != "dag_refine_gated":
            continue
        b = by.get((scen, n, "dag_refine_gated_batched"))
        if b is not None:
            batched_gated_tp[f"{scen}@n={n}"] = (
                b["moves_per_s"] / max(r["moves_per_s"], 1e-9))
    arch_rows = [r for r in results
                 if r["path"] == "arch_gated_quality"]
    return {"speedups": speedups,
            "min_speedup_at_512": min(s512.values()) if s512 else None,
            "quality_no_worse_than_reference": quality_ok,
            "event_move_throughput_ratios": event_tp,
            "event_delta_throughput_at_256": tp256[0] if tp256 else None,
            "gated_move_throughput_ratios": gated_tp,
            "batched_event_throughput_ratios": batched_tp,
            "min_batched_event_ratio_at_512plus": (
                min(tp512plus) if tp512plus else None),
            "batched_gated_throughput_ratios": batched_gated_tp,
            "batched_arch_quality_ok": (
                all(r["batched_no_worse"] for r in arch_rows)
                if arch_rows else None)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_scheduler_scaling.json")
    ap.add_argument("--max-ref-n", type=int, default=512)
    ap.add_argument("--max-event-full-n", type=int, default=256)
    ap.add_argument("--max-gated-full-n", type=int, default=128)
    ap.add_argument("--max-batched-n", type=int, default=max(BATCHED_NS),
                    help="largest n for the batched refine cells "
                         "(check_regression re-runs only up to its own "
                         "smaller default)")
    ap.add_argument("--no-arch-quality", action="store_true",
                    help="skip the traced-arch batched-vs-sequential "
                         "gated quality pin")
    ap.add_argument("--full", action="store_true",
                    help="run the reference path at every n")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=2,
                    help="best-of-k wall times for the guarded cells")
    args = ap.parse_args(argv)
    max_ref = max(NS) if args.full else args.max_ref_n
    out = run(max_ref_n=max_ref, seed=args.seed,
              max_event_full_n=args.max_event_full_n,
              max_gated_full_n=args.max_gated_full_n,
              repeats=args.repeats,
              max_batched_n=args.max_batched_n,
              arch_quality=not args.no_arch_quality)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
