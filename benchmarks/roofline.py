"""Roofline table benchmark: three terms per (arch x shape) from the
dry-run JSON artifacts (see repro.launch.dryrun / EXPERIMENTS.md)."""

from __future__ import annotations

import os

from repro.roofline import analyse

__all__ = ["run"]


def run(print_fn=print, path: str | None = None) -> list[dict]:
    path = path or os.environ.get("DRYRUN_JSON", "dryrun_singlepod.json")
    if not os.path.exists(path):
        print_fn(f"# roofline: {path} missing — run "
                 "`python -m repro.launch.dryrun --all --out {path}` first")
        return []
    rows = analyse(path)
    print_fn("# Roofline terms per (arch x shape), single-pod 16x16")
    print_fn("arch,shape,peak_gib,t_compute_ms,t_memory_ms,"
             "t_collective_ms,dominant,roofline_frac,useful_flops_ratio")
    for r in rows:
        if "skipped" in r:
            print_fn(f"{r['arch']},{r['shape']},skipped({r['skipped'][:40]})"
                     ",,,,,,")
            continue
        if "error" in r:
            print_fn(f"{r['arch']},{r['shape']},ERROR,,,,,,")
            continue
        print_fn(f"{r['arch']},{r['shape']},{r['peak_gib']:.2f},"
                 f"{r['t_compute_s'] * 1e3:.2f},{r['t_memory_s'] * 1e3:.2f},"
                 f"{r['t_collective_s'] * 1e3:.2f},{r['dominant']},"
                 f"{r['roofline_fraction']:.3f},"
                 f"{r['useful_flops_ratio']:.3f}")
    return rows
