"""TPU adaptation benchmark: symbiotic serving-round composition.

Continuous-batching simulation at production scale (7B-class weights,
realistic KV sizes): requests arrive over time, every live request
contributes exactly one work item per engine iteration (a prefill
chunk, or ONE decode step — step t+1 depends on step t), and the
scheduler composes the iteration's execution rounds under a token
budget.  Total modelled time is the sum of occupancy-adjusted roofline
round times; the decode weight stream is charged once per round, so
hiding decode steps under prefill compute is the win the paper's
reordering delivers here.

Policies:
* ``fifo``          — arrival-order packing (head-of-line prefill blocks),
* ``symbiotic``     — Algorithm 1 round composition (unmodified; the
  vectorized incremental path, identical rounds to the reference),
* ``refined``       — + local search under the TPU round cost model
  (weight stream charged once per re-rounded candidate),
* ``refined-round`` / ``refined-event`` — + local search on the flat
  launch order under the corresponding **core simulator** model,
  delta-evaluated (the ``refine_model`` axis: how much the richer
  event-model objective buys end-to-end vs the round model).

A second section runs the *real* ``ServingEngine`` (smoke-size model,
greedy decode on CPU) and reports its ``ScheduleCache`` hit-rate:
steady-state decode-heavy steps reuse the previous round composition
instead of re-running greedy + guard + refine every ``step()`` — plus
(PR 9) the engine's full metrics snapshot, per-request latency
quantiles and the online quality-audit counters.  A third sweeps the
cache's ``kv_bucket`` quantization under a long-tail kv-len
distribution, reporting hit-rate vs modelled regret (cached
composition time vs an uncached run of the same workload).  A fourth
(``audit_bench``) re-runs the paper's Fig.-1 percentile protocol
through the *online* :class:`repro.obs.QualityAuditor` on the traced
arch workloads at four cores — the acceptance gate that served
refined compositions land at or above the 90th percentile of 50
seeded random topological orders.  A fifth (``frontend_bench``, PR 10)
drives the async continuous-batching front end
(``repro.serve.frontend``) with seeded Poisson/bursty/diurnal arrivals
on its virtual clock and reports p50/p99 request latency, goodput and
rejection rate per traced arch — with frontend-served tokens asserted
bit-identical to the synchronous ``step()`` baseline.

``python benchmarks/serving.py`` writes every section to
``BENCH_serving.json``.
"""

from __future__ import annotations

import argparse
import json
import random
from dataclasses import dataclass, field

from repro.core import greedy_order_fast
from repro.core.refine import refine_order
from repro.core.tpu import (decode_profile, fifo_rounds,
                            make_serving_device, prefill_profile,
                            round_time)

__all__ = ["run", "simulate_load", "engine_cache_stats",
           "kv_bucket_sweep", "churn_compose_bench", "audit_bench",
           "frontend_bench"]

#: budget for the refine_model axis rows (full-simulation equivalents;
#: the event model delta path stretches this ~10x in effective moves)
REFINE_MODEL_BUDGET = 100

N_PARAMS = 7e9
KVB = 131072.0      # bytes/token (32L x 8kv x 128hd x 2 x bf16)
WEIGHTS = 2 * N_PARAMS


@dataclass
class _Req:
    rid: int
    prompt: int
    n_decode: int
    prefill_done: int = 0
    done_tokens: int = 0

    @property
    def prefilled(self) -> bool:
        return self.prefill_done >= self.prompt

    def done(self) -> bool:
        return self.prefilled and self.done_tokens >= self.n_decode


def _mk_requests(kind: str, seed: int) -> list[tuple[int, _Req]]:
    """[(arrival_iteration, request)] for a load mix."""
    rng = random.Random(seed)
    reqs = []
    rid = 0
    if kind == "prefill-heavy":
        spec = [(2048, 32, 10), (1024, 32, 10)]
    elif kind == "balanced":
        spec = [(1024, 64, 12), (512, 128, 20)]
    else:  # decode-heavy
        spec = [(2048, 256, 6), (256, 256, 30)]
    for prompt, n, per_it in spec:
        for i in range(n):
            reqs.append((i // per_it, _Req(rid, prompt,
                                           rng.randint(8, 24))))
            rid += 1
    return reqs


def simulate_load(kind: str, policy: str, *, seed: int = 3,
                  token_budget: int = 2048, prefill_chunk: int = 512,
                  max_iters: int = 3000) -> dict:
    """``prefill_chunk``: prompts are prefilled in chunks (the
    elastic-kernel/Sarathi move) so compute-bound chunks can co-schedule
    with decode batches every round — both policies get it."""
    device = make_serving_device(token_budget=token_budget,
                                 hbm_round_budget=float(64 << 30))
    arrivals = _mk_requests(kind, seed)
    live: list[_Req] = []
    t_total, n_rounds, it = 0.0, 0, 0
    while it < max_iters:
        live += [r for a, r in arrivals if a == it]
        arrivals = [(a, r) for a, r in arrivals if a > it]
        pending = [r for r in live if not r.done()]
        if not pending and not arrivals:
            break
        items, by = [], {}
        for r in pending:
            if not r.prefilled:
                chunk = min(prefill_chunk, r.prompt - r.prefill_done)
                itp = prefill_profile(f"p{r.rid}", n_params=N_PARAMS,
                                      seq_len=chunk,
                                      kv_bytes_per_token=KVB)
            else:
                itp = decode_profile(f"d{r.rid}", n_params=N_PARAMS,
                                     kv_len=r.prompt + r.done_tokens,
                                     kv_bytes_per_token=KVB)
            items.append(itp)
            by[itp.name] = (itp, r)
        # compose rounds
        if policy == "fifo":
            rounds = fifo_rounds(items, device)
        else:
            profs = [i.profile() for i in items]
            sched = greedy_order_fast(profs, device)
            if policy == "refined":
                def tfn(order):
                    its = [by[p.name][0] for p in order]
                    rds = fifo_rounds(its, device)
                    return sum(round_time(r, device, WEIGHTS) for r in rds)

                order, _, _ = refine_order(sched.order, device,
                                           time_fn=tfn, budget=400)
                rounds = fifo_rounds([by[p.name][0] for p in order],
                                     device)
            elif policy in ("refined-round", "refined-event"):
                # the refine_model axis: flat-order refinement under
                # the core simulator, delta-evaluated via the
                # checkpointing DeltaEvaluator, then re-rounded
                order, _, _ = refine_order(
                    sched.order, device, model=policy.split("-")[1],
                    budget=REFINE_MODEL_BUDGET, neighborhood="auto")
                rounds = fifo_rounds([by[p.name][0] for p in order],
                                     device)
            else:
                rounds = [[by[p.name][0] for p in rd.kernels]
                          for rd in sched.rounds]
        for rd in rounds:
            t_total += round_time(rd, device, WEIGHTS)
            n_rounds += 1
            for itp in rd:
                _, r = by[itp.name]
                if not r.prefilled:
                    r.prefill_done += itp.tokens
                else:
                    r.done_tokens += 1
        it += 1
    tokens = sum(r.done_tokens + 1 for r in live)
    return {"kind": kind, "policy": policy, "iters": it,
            "rounds": n_rounds, "time_s": t_total,
            "tokens": tokens, "tok_per_s": tokens / max(t_total, 1e-12)}


def _print_phases(phases: dict, print_fn) -> None:
    """One human-readable line per engine phase (the PR 8 profiling
    hooks): calls, total wall seconds, mean per call."""
    for name, row in phases.items():
        if row["calls"] == 0:
            continue
        print_fn(f"  phase {name:<8} {row['calls']:>4} calls, "
                 f"{row['total_s'] * 1e3:8.2f} ms total, "
                 f"{row['mean_s'] * 1e6:8.1f} us/call")


def engine_cache_stats(*, n_requests: int = 6, max_new_tokens: int = 24,
                       print_fn=print) -> dict:
    """ScheduleCache hit-rate of the real engine on a decode-heavy
    steady state (smoke-size model, CPU greedy decode), with staggered
    arrivals so cache *near-misses* (one request joining the mix)
    exercise the warm-start path.  Also prints the per-phase wall-clock
    breakdown (PR 8 profiling hooks) and runs a short churny
    ``composition="incremental"`` engine so the PR 7 churn counters
    (``incremental_joins`` / ``incremental_leaves`` /
    ``frontier_rebuilds``) show up in the human-readable summary, not
    just the JSON."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.obs import MetricsRegistry
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, params, max_len=64,
                        policy=SchedulerPolicy(kind="symbiotic",
                                               warm_audit_frac=1.0,
                                               audit_frac=0.25),
                        metrics=MetricsRegistry())
    eng.submit([Request(i, rng.integers(0, 512, size=4),
                        max_new_tokens=max_new_tokens)
                for i in range(n_requests)])
    late = [(4, [Request(100, rng.integers(0, 512, size=4),
                         max_new_tokens=max_new_tokens // 2)]),
            (8, [Request(101, rng.integers(0, 512, size=4),
                         max_new_tokens=max_new_tokens // 2)])]
    stats = eng.run(arrivals=late)
    cache = stats["schedule_cache"]
    print_fn(f"engine ScheduleCache: {cache['hits']} hits / "
             f"{cache['misses']} misses "
             f"({cache['warm_hits']} warm starts, "
             f"{cache['warm_sampled']} audited, "
             f"warm regret {cache['warm_regret_mean']:+.2%}, "
             f"hit-rate {cache['hit_rate']:.1%}) over "
             f"{stats['rounds']} rounds, "
             f"{stats['total_new_tokens']} tokens")
    _print_phases(stats["phases"], print_fn)
    lat = stats["latency"]
    print_fn(f"  latency p50 {lat['p50_s'] * 1e3:.1f} ms, "
             f"p99 {lat['p99_s'] * 1e3:.1f} ms, "
             f"goodput {lat['goodput_rps']:.1f} req/s "
             f"({lat['goodput_tokens_per_s']:.0f} tok/s)")
    snap = stats["metrics"]
    print_fn(f"  online audit: {snap.get('audit_steps', 0.0):.0f} "
             f"steps audited, "
             f"{snap.get('audit_below_floor', 0.0):.0f} below floor")
    cache["phases"] = stats["phases"]
    # PR 9: the full registry snapshot + per-request latency block ride
    # into BENCH_serving.json so a regression in any series (audit,
    # drift, cache, phase timers) diffs in CI artifacts.
    cache["metrics"] = snap
    cache["latency"] = lat

    # churny incremental-composition run: the PR 7 counters are only
    # live on the respect_deps + composition="incremental" path
    inc = ServingEngine(cfg, params, max_len=64,
                        policy=SchedulerPolicy(
                            kind="symbiotic", respect_deps=True,
                            composition="incremental"),
                        metrics=MetricsRegistry())
    inc.submit([Request(i, rng.integers(0, 512, size=4),
                        max_new_tokens=3 + i) for i in range(3)])
    churny = [(2, [Request(110, rng.integers(0, 512, size=4),
                           max_new_tokens=2)]),
              (4, [Request(111, rng.integers(0, 512, size=4),
                           max_new_tokens=3)])]
    s_inc = inc.run(arrivals=churny)
    c_inc = s_inc["schedule_cache"]
    print_fn(f"incremental composition (churny): "
             f"{c_inc['incremental_joins']} joins, "
             f"{c_inc['incremental_leaves']} leaves, "
             f"{c_inc['frontier_rebuilds']} frontier rebuilds over "
             f"{s_inc['rounds']} rounds")
    _print_phases(s_inc["phases"], print_fn)
    cache["incremental"] = {
        "incremental_joins": c_inc["incremental_joins"],
        "incremental_leaves": c_inc["incremental_leaves"],
        "frontier_rebuilds": c_inc["frontier_rebuilds"],
        "phases": s_inc["phases"]}
    return cache


def kv_bucket_sweep(buckets=(64, 128, 256, 512), *, seed: int = 0,
                    print_fn=print) -> list[dict]:
    """ScheduleCache ``kv_bucket`` sensitivity under a long-tail
    kv-len distribution: hit-rate vs modelled regret.

    A coarse bucket hashes more steps onto cached patterns (higher
    hit-rate) but replays compositions farther from what a cold greedy
    would build for the drifted kv demands; ``modelled_regret`` is the
    modelled-time ratio of the cached run against an uncached run of
    the identical workload (generated tokens are exact and equal in
    both — only round composition differs).  Magnitude, not sign, is
    the fidelity signal: *negative* regret means the replayed pattern
    claimed a shorter modelled time than cold composition — typically
    a stale pattern packing drifted items into rounds the cold
    scheduler (which re-checks capacity against the actual demands)
    would have split, an optimism the roofline round model does not
    penalise.  The workload keeps several requests decoding
    concurrently at kv-lens from tens to ~300 and injects long-prompt
    arrivals mid-decode, so compute-bound prefill shares rounds with
    drifting decode items — without that, all-decode rounds are
    memory-bound and total time collapses to a function of the round
    count alone, pinning every regret at zero.
    """
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    #: long-tail decode lengths, deep and *concurrent*: the live mix
    #: spans kv-lens from tens to ~300 at once, so consecutive steps
    #: fall into genuinely different signature multisets per bucket
    #: width (short-lived requests alone would keep every signature in
    #: bucket 0 and make the sweep vacuous)
    tail_lens = (60, 80, 100, 120, 160, 200, 240, 280)
    #: hbm-tight round budget: decode items bring kv_len * kv_bytes of
    #: round traffic, so which kv-lens can share a round — the thing a
    #: coarse bucket blurs — is exactly what binds here.  (A vmem- or
    #: slot-bound budget would make partitioning kv-insensitive and
    #: pin the regret at 0 by construction.)
    device = make_serving_device(hbm_round_budget=float(1 << 20))

    def run_once(policy: SchedulerPolicy) -> dict:
        rng = np.random.default_rng(seed)
        eng = ServingEngine(cfg, params, max_len=320, device=device,
                            policy=policy)
        eng.submit([Request(i, rng.integers(0, 512, size=6),
                            max_new_tokens=n)
                    for i, n in enumerate(tail_lens)])
        # Long prompts arriving mid-decode: compute-bound prefill
        # items must share rounds with drifting decode items, so
        # round membership — what the replayed pattern fixes — moves
        # the modelled time (all-decode rounds are memory-bound and
        # their total time collapses to a function of the round count
        # alone, which would pin the sweep's regret at zero).
        late = [(it, [Request(100 + j,
                              rng.integers(0, 512, size=180),
                              max_new_tokens=24)])
                for j, it in enumerate((30, 90))]
        return eng.run(arrivals=late)

    cold = run_once(SchedulerPolicy(kind="symbiotic", cache=False))
    t_cold = cold["modelled_time_s"]
    out = []
    print_fn("# ScheduleCache kv_bucket sensitivity (long-tail kv-lens)")
    print_fn("kv_bucket,hit_rate,entries,regret_pct,revalidations,"
             "optimistic_regret_pct")
    for b in buckets:
        st = run_once(SchedulerPolicy(kind="symbiotic", kv_bucket=b))
        assert st["outputs"] == cold["outputs"], "tokens must be exact"
        # contrast run: optimistic replay (the pre-PR 4 behaviour,
        # replay_drift_tol disabled) shows what the stale-replay
        # re-validation buys at each bucket width
        opt = run_once(SchedulerPolicy(kind="symbiotic", kv_bucket=b,
                                       replay_drift_tol=0.0))
        assert opt["outputs"] == cold["outputs"], "tokens must be exact"
        cache = st["schedule_cache"]
        rec = {"kv_bucket": b,
               "hit_rate": cache["hit_rate"],
               "hits": cache["hits"], "misses": cache["misses"],
               "entries": cache["entries"],
               "replay_revalidations": cache["replay_revalidations"],
               "modelled_time_s": st["modelled_time_s"],
               "modelled_regret": st["modelled_time_s"] / t_cold - 1.0,
               "optimistic_regret":
                   opt["modelled_time_s"] / t_cold - 1.0}
        out.append(rec)
        print_fn(f"{b},{rec['hit_rate']:.3f},{rec['entries']},"
                 f"{rec['modelled_regret'] * 100:.2f},"
                 f"{rec['replay_revalidations']},"
                 f"{rec['optimistic_regret'] * 100:.2f}")
    return out


#: model-free stand-in for a populated KV cache: ``build_dag_triples``
#: only checks ``r.cache is None`` to pick prefill vs decode
_DECODED = object()


def _churn_steps(n_live: int, steps: int, churn: float, seed: int):
    """Deterministic join/leave trajectory: per-step snapshots
    ``[(rid, phase, prompt_len, pos), ...]`` around a target of
    ``n_live`` live requests, with Poisson(``churn``) joins and leaves
    per step.  Snapshots are plain tuples so the batch and incremental
    paths can rebuild *identical* request sets independently."""
    import numpy as np

    rng = np.random.default_rng(seed)
    nxt = 0
    live: list[list] = []

    def join(phase: str = "prefill"):
        nonlocal nxt
        plen = int(rng.integers(8, 64))
        pos = plen + int(rng.integers(1, 256)) if phase == "decode" else 0
        live.append([nxt, phase, plen, pos])
        nxt += 1

    for _ in range(n_live):
        join("decode")
    out = []
    for _ in range(steps):
        out.append([tuple(r) for r in live])
        for r in live:                       # advance one engine step
            if r[1] == "prefill":
                r[1], r[3] = "decode", r[2] + 1
            else:
                r[3] += 1
        for _ in range(int(rng.poisson(churn))):
            if len(live) > max(1, n_live // 2):
                live.pop(int(rng.integers(len(live))))
        for _ in range(int(rng.poisson(churn))):
            join()
    return out


def churn_compose_bench(cells=(16, 64), *, steps: int = 12,
                        churn: float = 2.0, seed: int = 0,
                        repeats: int = 3, print_fn=print) -> list[dict]:
    """Incremental vs batch *compose cost* under join/leave churn
    (PR 7).

    Model-free: requests are traced into per-layer chains
    (:func:`repro.serve.engine.build_dag_triples`) but never executed,
    so the cell isolates exactly what ``composition="incremental"``
    changes — the per-step scheduling work.  Both paths see identical
    step snapshots; the batch path recomposes cold every step
    (``Composer.compose_dag`` with the cache off), the incremental
    path extends/retires the live :class:`GreedyFrontier`
    (:class:`repro.serve.live.LiveComposition`).  ``compose_speedup``
    compares steady-state means (the incremental path's step 0 *is* a
    cold build, so it is excluded from both means), best-of-
    ``repeats`` per path — the same min-of-k wall protocol as
    ``benchmarks/scaling.py``; ``modelled_regret_mean`` is the mean
    per-step modelled round-time ratio minus one — what keeping the
    composition warm costs in schedule quality, in the same round
    currency the engine guard uses.
    """
    import time

    import numpy as np

    from repro.configs import get_config
    from repro.graph.kernel_graph import (arch_kv_bytes_per_token,
                                          estimate_n_params)
    from repro.serve import (Composer, LiveComposition, Request,
                             ScheduleCache, SchedulerPolicy,
                             build_dag_triples)

    cfg = get_config("qwen1.5-0.5b", "smoke")
    n_params = estimate_n_params(cfg)
    kvb = arch_kv_bytes_per_token(cfg)
    device = make_serving_device()
    weights = 2.0 * n_params

    def reqs_of(snap):
        reqs = []
        for rid, phase, plen, pos in snap:
            r = Request(rid, np.zeros(plen, np.int32))
            if phase == "decode":
                r.cache, r.pos = _DECODED, pos
            reqs.append(r)
        return reqs

    def run_path(snaps, composition: str):
        pol = SchedulerPolicy(kind="symbiotic", respect_deps=True,
                              cache=False, composition=composition)
        cache = ScheduleCache()
        comp = Composer(pol, device, weights, cache)
        live = (LiveComposition(comp) if composition == "incremental"
                else None)
        wall, modelled = [], []
        for snap in snaps:
            triples, traced = build_dag_triples(
                cfg, reqs_of(snap), n_params=n_params,
                kv_bytes_per_token=kvb)
            t0 = time.perf_counter()
            rounds = (live.compose_dag(triples, traced) if live
                      else comp.compose_dag(triples, traced))
            wall.append(time.perf_counter() - t0)
            modelled.append(sum(comp.dag_round_time(rd)
                                for rd in rounds))
        return wall, modelled, cache.stats()

    out = []
    print_fn("# Incremental vs batch compose cost under churn "
             "(traced qwen chains, model-free)")
    print_fn("n_live,steps,batch_ms_per_step,incremental_ms_per_step,"
             "speedup,modelled_regret_pct,joins,leaves,rebuilds")
    for n_live in cells:
        snaps = _churn_steps(n_live, steps, churn, seed)
        # steady state: step 0 is the incremental path's cold seed
        mean = lambda xs: sum(xs) / max(len(xs), 1)  # noqa: E731
        t_batch = t_inc = float("inf")
        for _ in range(max(repeats, 1)):
            w_b, m_b, _ = run_path(snaps, "batch")
            w_i, m_i, st = run_path(snaps, "incremental")
            t_batch = min(t_batch, mean(w_b[1:]))
            t_inc = min(t_inc, mean(w_i[1:]))
        regret = mean([ti / tb - 1.0 for ti, tb in
                       zip(m_i[1:], m_b[1:])])
        rec = {"n_live": n_live, "steps": steps, "churn": churn,
               "repeats": max(repeats, 1),
               "batch_compose_s_per_step": t_batch,
               "incremental_compose_s_per_step": t_inc,
               "compose_speedup": t_batch / max(t_inc, 1e-12),
               "modelled_regret_mean": regret,
               "incremental_joins": st["incremental_joins"],
               "incremental_leaves": st["incremental_leaves"],
               "frontier_rebuilds": st["frontier_rebuilds"]}
        out.append(rec)
        print_fn(f"{n_live},{steps},{t_batch * 1e3:.1f},"
                 f"{t_inc * 1e3:.1f},{rec['compose_speedup']:.2f},"
                 f"{regret * 100:.2f},{st['incremental_joins']},"
                 f"{st['incremental_leaves']},"
                 f"{st['frontier_rebuilds']}")
    return out


#: the offline Fig.-1 request mix (``repro.graph.kernel_graph``
#: default trace): two prefill chunks plus a long-tail of decode
#: steps, the shape ``benchmarks/dag.py`` scores at 200 random orders
_AUDIT_REQS = (("prefill", 512), ("prefill", 256),
               ("decode", 512), ("decode", 1024), ("decode", 2048),
               ("decode", 3072), ("decode", 4096), ("decode", 6144))

#: the paper's percentile claim, as the bench's pass line
_AUDIT_FLOOR = 90.0


def audit_bench(*, k: int = 50, seed: int = 0, max_stages: int = 16,
                print_fn=print) -> list[dict]:
    """Online Fig.-1 audit of served refined compositions (PR 9).

    Model-free: each traced arch workload (full config, coarsened to
    ``max_stages`` stages per request, same trace as
    ``benchmarks/dag.py``) is composed once by the real
    ``kind="refined"`` / ``refine_model="gated"`` pipeline on the
    four-core serving device, then scored by the composer's own
    :class:`repro.obs.QualityAuditor` against ``k`` seeded random
    topological orders under the gated-event makespan.  The acceptance
    line is the paper's claim live: every arch's served composition
    must land at or above the 90th percentile.  ``sims_saved`` shows
    the checkpoint reuse that makes the online audit affordable —
    baselines resume from the served order's cached prefix states
    instead of paying ``k`` full simulations.
    """
    import numpy as np

    from repro.configs import get_config
    from repro.graph.kernel_graph import (arch_kv_bytes_per_token,
                                          estimate_n_params)
    from repro.serve import (Composer, Request, ScheduleCache,
                             SchedulerPolicy, build_dag_triples)

    device = make_serving_device(n_units=4)
    out = []
    print_fn("# Online quality audit: served refined composition vs "
             f"{k} random topological orders (gated model, x4 cores)")
    print_fn("arch,n_items,rounds,percentile,below_floor,sims_saved")
    for arch in ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b"):
        cfg = get_config(arch, "full")
        n_params = estimate_n_params(cfg)
        kvb = arch_kv_bytes_per_token(cfg)
        reqs = []
        for rid, (phase, n) in enumerate(_AUDIT_REQS):
            r = Request(rid, np.zeros(n, np.int32))
            if phase == "decode":
                r.cache, r.pos = _DECODED, n
            reqs.append(r)
        triples, traced = build_dag_triples(
            cfg, reqs, n_params=n_params, kv_bytes_per_token=kvb,
            max_stages=max_stages)
        pol = SchedulerPolicy(kind="refined", respect_deps=True,
                              refine_model="gated", dag_guard="gated",
                              cache=False, audit_frac=1.0, audit_k=k,
                              audit_floor=_AUDIT_FLOOR,
                              audit_seed=seed)
        comp = Composer(pol, device, 2.0 * n_params, ScheduleCache())
        rounds = comp.compose_dag(triples, traced)
        verdict = comp.auditor.audit_dag(rounds, traced,
                                         arch=f"{arch}@x4",
                                         kind="refined")
        assert verdict is not None, f"audit skipped for {arch}"
        rec = {"arch": arch, "device": device.name, "k": verdict["k"],
               "n_items": traced.graph.n, "rounds": len(rounds),
               "percentile": verdict["percentile"],
               "t_served_s": verdict["t_served"],
               "below_floor": verdict["below_floor"],
               "floor": verdict["floor"],
               "sims_saved": verdict["sims_saved"]}
        out.append(rec)
        print_fn(f"{arch},{rec['n_items']},{rec['rounds']},"
                 f"{rec['percentile']:.1f},{rec['below_floor']},"
                 f"{rec['sims_saved']:.1f}")
    return out


def frontend_bench(*, n_requests: int = 8, rate: float = 1e6,
                   seed: int = 0, n_replicas: int = 2,
                   print_fn=print) -> list[dict]:
    """Async serving front end vs the synchronous baseline (PR 10).

    Drives all three traced archs (smoke size) with seeded
    Poisson/bursty/diurnal arrival processes through
    ``repro.serve.frontend`` — cost-modelled admission, cache-aware
    routing over ``n_replicas`` engine replicas, virtual clock — and
    reports p50/p99 request latency, queue depth, goodput and
    rejection rate per (arch, process) cell.  Every cell also replays
    the identical request set through a bare synchronous
    ``ServingEngine.step()`` loop and records
    ``tokens_bit_identical``: the front end may reorder and batch, but
    must not change a single served token.  All latency numbers are in
    *virtual* (modelled roofline) seconds — deterministic by seed, so
    this section is byte-stable in ``BENCH_serving.json``.
    """
    import jax

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import (LoadGenerator, SchedulerPolicy,
                             ServingEngine, ServingFrontend)

    out = []
    print_fn("# Async front end (virtual clock, smoke archs, "
             f"{n_replicas} replicas)")
    print_fn("arch,process,completed,p50_us,p99_us,goodput_rps,"
             "reject_rate,identical")
    for arch in ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b"):
        cfg = get_config(arch, "smoke")
        params = T.init(jax.random.PRNGKey(0), cfg)
        for process in ("poisson", "bursty", "diurnal"):
            gen = LoadGenerator(process=process, n_requests=n_requests,
                                rate=rate, seed=seed,
                                max_new_tokens=(2, 4))
            fe = ServingFrontend.build(cfg, params,
                                       n_replicas=n_replicas,
                                       max_len=32,
                                       policy=SchedulerPolicy())
            rep = gen.drive(fe)
            sync = ServingEngine(cfg, params, max_len=32,
                                 policy=SchedulerPolicy())
            sync.submit([r for _, r in gen.workload()])
            rep["arch"] = arch
            rep["n_replicas"] = n_replicas
            rep["tokens_bit_identical"] = bool(
                fe.outputs() == sync.run()["outputs"])
            out.append(rep)
            print_fn(f"{arch},{process},{rep['completed']},"
                     f"{rep['p50_s'] * 1e6:.3f},"
                     f"{rep['p99_s'] * 1e6:.3f},"
                     f"{rep['goodput_rps']:.0f},"
                     f"{rep['rejection_rate']:.3f},"
                     f"{rep['tokens_bit_identical']}")
    return out


#: the refine_model axis rides along with the classic three policies
_POLICIES = ("fifo", "symbiotic", "refined", "refined-round",
             "refined-event")


def run(print_fn=print, with_engine: bool = True,
        with_kv_sweep: bool = True, with_churn: bool = True,
        with_audit: bool = True, with_frontend: bool = True) -> dict:
    print_fn("# Symbiotic continuous batching (7B cost model, v5e)")
    print_fn("mix,policy,rounds,time_ms,tok_per_s,speedup_vs_fifo")
    mixes = []
    for kind in ("prefill-heavy", "balanced", "decode-heavy"):
        base = None
        for policy in _POLICIES:
            r = simulate_load(kind, policy)
            if base is None:
                base = r["time_s"]
            r["speedup_vs_fifo"] = base / r["time_s"]
            mixes.append(r)
            print_fn(f"{kind},{policy},{r['rounds']},"
                     f"{r['time_s'] * 1e3:.1f},{r['tok_per_s']:.0f},"
                     f"{r['speedup_vs_fifo']:.3f}")
    out = {"benchmark": "serving",
           "refine_model_budget": REFINE_MODEL_BUDGET,
           "mixes": mixes}
    if with_engine:
        print_fn("# ServingEngine schedule-cache (decode-heavy steady state)")
        out["engine_cache"] = engine_cache_stats(print_fn=print_fn)
    if with_kv_sweep:
        out["kv_bucket_sweep"] = kv_bucket_sweep(print_fn=print_fn)
    if with_churn:
        out["churn"] = churn_compose_bench(print_fn=print_fn)
    if with_audit:
        out["audit"] = audit_bench(print_fn=print_fn)
    if with_frontend:
        out["frontend_bench"] = frontend_bench(print_fn=print_fn)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--no-engine", action="store_true",
                    help="skip the real-engine sections (cost-model "
                         "mixes only)")
    ap.add_argument("--no-churn", action="store_true",
                    help="skip the incremental-vs-batch churn cell "
                         "(model-free wall-clock measurement)")
    ap.add_argument("--no-audit", action="store_true",
                    help="skip the online Fig.-1 quality audit of "
                         "served refined compositions")
    ap.add_argument("--no-frontend", action="store_true",
                    help="skip the async front-end load-generator "
                         "section (virtual-clock latency report)")
    args = ap.parse_args(argv)
    out = run(with_engine=not args.no_engine,
              with_kv_sweep=not args.no_engine,
              with_churn=not args.no_churn,
              with_audit=not args.no_audit,
              with_frontend=not args.no_frontend)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
