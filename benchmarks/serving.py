"""TPU adaptation benchmark: symbiotic serving-round composition.

Continuous-batching simulation at production scale (7B-class weights,
realistic KV sizes): requests arrive over time, every live request
contributes exactly one work item per engine iteration (a prefill
chunk, or ONE decode step — step t+1 depends on step t), and the
scheduler composes the iteration's execution rounds under a token
budget.  Total modelled time is the sum of occupancy-adjusted roofline
round times; the decode weight stream is charged once per round, so
hiding decode steps under prefill compute is the win the paper's
reordering delivers here.

Policies:
* ``fifo``      — arrival-order packing (head-of-line prefill blocks),
* ``symbiotic`` — Algorithm 1 round composition (unmodified; the
  vectorized incremental path, identical rounds to the reference),
* ``refined``   — + local search under the round cost model.

A second section runs the *real* ``ServingEngine`` (smoke-size model,
greedy decode on CPU) and reports its ``ScheduleCache`` hit-rate:
steady-state decode-heavy steps reuse the previous round composition
instead of re-running greedy + guard + refine every ``step()``.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

from repro.core import greedy_order_fast
from repro.core.refine import refine_order
from repro.core.tpu import (decode_profile, fifo_rounds,
                            make_serving_device, prefill_profile,
                            round_time)

__all__ = ["run", "simulate_load", "engine_cache_stats"]

N_PARAMS = 7e9
KVB = 131072.0      # bytes/token (32L x 8kv x 128hd x 2 x bf16)
WEIGHTS = 2 * N_PARAMS


@dataclass
class _Req:
    rid: int
    prompt: int
    n_decode: int
    prefill_done: int = 0
    done_tokens: int = 0

    @property
    def prefilled(self) -> bool:
        return self.prefill_done >= self.prompt

    def done(self) -> bool:
        return self.prefilled and self.done_tokens >= self.n_decode


def _mk_requests(kind: str, seed: int) -> list[tuple[int, _Req]]:
    """[(arrival_iteration, request)] for a load mix."""
    rng = random.Random(seed)
    reqs = []
    rid = 0
    if kind == "prefill-heavy":
        spec = [(2048, 32, 10), (1024, 32, 10)]
    elif kind == "balanced":
        spec = [(1024, 64, 12), (512, 128, 20)]
    else:  # decode-heavy
        spec = [(2048, 256, 6), (256, 256, 30)]
    for prompt, n, per_it in spec:
        for i in range(n):
            reqs.append((i // per_it, _Req(rid, prompt,
                                           rng.randint(8, 24))))
            rid += 1
    return reqs


def simulate_load(kind: str, policy: str, *, seed: int = 3,
                  token_budget: int = 2048, prefill_chunk: int = 512,
                  max_iters: int = 3000) -> dict:
    """``prefill_chunk``: prompts are prefilled in chunks (the
    elastic-kernel/Sarathi move) so compute-bound chunks can co-schedule
    with decode batches every round — both policies get it."""
    device = make_serving_device(token_budget=token_budget,
                                 hbm_round_budget=float(64 << 30))
    arrivals = _mk_requests(kind, seed)
    live: list[_Req] = []
    t_total, n_rounds, it = 0.0, 0, 0
    while it < max_iters:
        live += [r for a, r in arrivals if a == it]
        arrivals = [(a, r) for a, r in arrivals if a > it]
        pending = [r for r in live if not r.done()]
        if not pending and not arrivals:
            break
        items, by = [], {}
        for r in pending:
            if not r.prefilled:
                chunk = min(prefill_chunk, r.prompt - r.prefill_done)
                itp = prefill_profile(f"p{r.rid}", n_params=N_PARAMS,
                                      seq_len=chunk,
                                      kv_bytes_per_token=KVB)
            else:
                itp = decode_profile(f"d{r.rid}", n_params=N_PARAMS,
                                     kv_len=r.prompt + r.done_tokens,
                                     kv_bytes_per_token=KVB)
            items.append(itp)
            by[itp.name] = (itp, r)
        # compose rounds
        if policy == "fifo":
            rounds = fifo_rounds(items, device)
        else:
            profs = [i.profile() for i in items]
            sched = greedy_order_fast(profs, device)
            if policy == "refined":
                def tfn(order):
                    its = [by[p.name][0] for p in order]
                    rds = fifo_rounds(its, device)
                    return sum(round_time(r, device, WEIGHTS) for r in rds)

                order, _, _ = refine_order(sched.order, device,
                                           time_fn=tfn, budget=400)
                rounds = fifo_rounds([by[p.name][0] for p in order],
                                     device)
            else:
                rounds = [[by[p.name][0] for p in rd.kernels]
                          for rd in sched.rounds]
        for rd in rounds:
            t_total += round_time(rd, device, WEIGHTS)
            n_rounds += 1
            for itp in rd:
                _, r = by[itp.name]
                if not r.prefilled:
                    r.prefill_done += itp.tokens
                else:
                    r.done_tokens += 1
        it += 1
    tokens = sum(r.done_tokens + 1 for r in live)
    return {"kind": kind, "policy": policy, "iters": it,
            "rounds": n_rounds, "time_s": t_total,
            "tokens": tokens, "tok_per_s": tokens / max(t_total, 1e-12)}


def engine_cache_stats(*, n_requests: int = 6, max_new_tokens: int = 24,
                       print_fn=print) -> dict:
    """ScheduleCache hit-rate of the real engine on a decode-heavy
    steady state (smoke-size model, CPU greedy decode), with staggered
    arrivals so cache *near-misses* (one request joining the mix)
    exercise the warm-start path."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import Request, SchedulerPolicy, ServingEngine

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    eng = ServingEngine(cfg, params, max_len=64,
                        policy=SchedulerPolicy(kind="symbiotic"))
    eng.submit([Request(i, rng.integers(0, 512, size=4),
                        max_new_tokens=max_new_tokens)
                for i in range(n_requests)])
    late = [(4, [Request(100, rng.integers(0, 512, size=4),
                         max_new_tokens=max_new_tokens // 2)]),
            (8, [Request(101, rng.integers(0, 512, size=4),
                         max_new_tokens=max_new_tokens // 2)])]
    stats = eng.run(arrivals=late)
    cache = stats["schedule_cache"]
    print_fn(f"engine ScheduleCache: {cache['hits']} hits / "
             f"{cache['misses']} misses "
             f"({cache['warm_hits']} warm starts, "
             f"hit-rate {cache['hit_rate']:.1%}) over "
             f"{stats['rounds']} rounds, "
             f"{stats['total_new_tokens']} tokens")
    return cache


def run(print_fn=print, with_engine: bool = True) -> list[dict]:
    print_fn("# Symbiotic continuous batching (7B cost model, v5e)")
    print_fn("mix,policy,rounds,time_ms,tok_per_s,speedup_vs_fifo")
    out = []
    for kind in ("prefill-heavy", "balanced", "decode-heavy"):
        base = None
        for policy in ("fifo", "symbiotic", "refined"):
            r = simulate_load(kind, policy)
            if base is None:
                base = r["time_s"]
            r["speedup_vs_fifo"] = base / r["time_s"]
            out.append(r)
            print_fn(f"{kind},{policy},{r['rounds']},"
                     f"{r['time_s'] * 1e3:.1f},{r['tok_per_s']:.0f},"
                     f"{r['speedup_vs_fifo']:.3f}")
    if with_engine:
        print_fn("# ServingEngine schedule-cache (decode-heavy steady state)")
        out.append({"kind": "engine-cache",
                    **engine_cache_stats(print_fn=print_fn)})
    return out
