"""Paper Table 3: the six concurrent-kernel experiments.

For each experiment, evaluate EVERY permutation of the launch order in
the event-driven per-SM simulator, then report the paper's four
metrics for Algorithm 1's order — optimal/worst/algorithm time,
percentile rank, speedup over worst, deviation from optimal — plus the
same for the beyond-paper refined scheduler.
"""

from __future__ import annotations

import itertools
import random

import numpy as np

from repro.core import (GTX580, EXPERIMENTS, greedy_order_fast,
                        percentile_rank, simulate)
from repro.core.refine import refined_schedule

__all__ = ["run", "rows"]

#: experiments with >6 kernels use a random sample of this many perms
#: for percentile estimation (the paper's 8! = 40,320 full space is
#: evaluated by fig1.py once; here we keep runtime bounded).
SAMPLE = 5000


def _space(kernels) -> np.ndarray:
    n = len(kernels)
    if n <= 6:
        perms = itertools.permutations(range(n))
    else:
        rng = random.Random(7)
        perms = (tuple(rng.sample(range(n), n)) for _ in range(SAMPLE))
    return np.array([simulate([kernels[i] for i in p], GTX580)
                     for p in perms])


def rows() -> list[dict]:
    out = []
    for name in EXPERIMENTS:
        ks = EXPERIMENTS[name]()
        sched = greedy_order_fast(ks, GTX580)
        t_alg = simulate(sched.order, GTX580)
        _, t_ref = refined_schedule(ks, GTX580)
        times = _space(ks)
        t_opt, t_worst = float(times.min()), float(times.max())
        out.append({
            "experiment": name,
            "optimal_ms": t_opt * 1e3,
            "worst_ms": t_worst * 1e3,
            "algorithm_ms": t_alg * 1e3,
            "refined_ms": t_ref * 1e3,
            "percentile": percentile_rank(t_alg, times),
            "refined_percentile": percentile_rank(t_ref, times),
            "speedup_over_worst": t_worst / t_alg,
            "deviation_from_optimal_pct": (t_alg / t_opt - 1) * 100,
            "refined_deviation_pct": (t_ref / t_opt - 1) * 100,
        })
    return out


def run(print_fn=print) -> list[dict]:
    rs = rows()
    print_fn("# Table 3 reproduction (event-driven per-SM simulator)")
    print_fn("experiment,optimal_ms,worst_ms,algorithm_ms,refined_ms,"
             "pctile,refined_pctile,speedup_worst,dev_opt_pct,"
             "refined_dev_pct")
    for r in rs:
        print_fn(f"{r['experiment']},{r['optimal_ms']:.2f},"
                 f"{r['worst_ms']:.2f},{r['algorithm_ms']:.2f},"
                 f"{r['refined_ms']:.2f},{r['percentile']:.1f},"
                 f"{r['refined_percentile']:.1f},"
                 f"{r['speedup_over_worst']:.3f},"
                 f"{r['deviation_from_optimal_pct']:.2f},"
                 f"{r['refined_deviation_pct']:.2f}")
    return rs
