"""Regenerate the dry-run + roofline tables inside EXPERIMENTS.md from
the sweep JSON artifacts.  Usage:

  PYTHONPATH=src python -m benchmarks.gen_experiments
"""

from __future__ import annotations

import json
import os

from repro.roofline import analyse

MARK_ROOF = "<!-- ROOFLINE_TABLE -->"
MARK_DRY = "<!-- DRYRUN_TABLES -->"


def _fmt_ms(s):
    return f"{s * 1e3:.2f}"


def roofline_md(path: str) -> str:
    rows = analyse(path)
    out = ["| arch | shape | peak GiB | t_compute ms | t_memory ms | "
           "t_collective ms | dominant | roofline frac | useful FLOPs |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | skipped | | | | "
                       f"{r['skipped'][:48]} | | |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['peak_gib']:.2f} | "
            f"{_fmt_ms(r['t_compute_s'])} | {_fmt_ms(r['t_memory_s'])} | "
            f"{_fmt_ms(r['t_collective_s'])} | {r['dominant']} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} |")
    return "\n".join(out)


def dryrun_md(path: str, title: str) -> str:
    with open(path) as f:
        recs = json.load(f)
    out = [f"### {title}", "",
           "| arch | shape | peak GiB/dev | raw HLO GFLOPs/dev | "
           "collective GiB/dev | lower s | compile s |",
           "|---|---|---|---|---|---|---|"]
    for r in recs:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — skipped: "
                       f"{r['skipped'][:60]} | | | | |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | ERROR "
                       f"{r['error'][:60]} | | | | |")
            continue
        coll = sum(r.get("collectives", {}).values()) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{r['memory']['peak_bytes'] / 2**30:.2f} | "
            f"{r['cost']['flops'] / 1e9:.1f} | {coll:.2f} | "
            f"{r['lower_s']} | {r['compile_s']} |")
    return "\n".join(out)


def main() -> None:
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    roof = roofline_md("dryrun_singlepod.json")
    dry = (dryrun_md("dryrun_singlepod.json",
                     "Single-pod 16x16 (256 chips)") + "\n\n" +
           dryrun_md("dryrun_multipod.json",
                     "Multi-pod 2x16x16 (512 chips)"))
    text = text.replace(MARK_ROOF, MARK_ROOF + "\n\n" + roof, 1)
    text = text.replace(MARK_DRY, MARK_DRY + "\n\n## Appendix: raw "
                        "dry-run tables\n\n" + dry, 1)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print("EXPERIMENTS.md tables regenerated")


if __name__ == "__main__":
    main()
