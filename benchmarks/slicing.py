"""Kernel slicing on oversized-stage workloads: sliced vs unsliced
ready-set greedy under the gated event model (Fig. 1 protocol).

A serving stage whose token footprint exceeds the device's slot budget
(a long prefill chunk against any layer stage) can never share a round
— the DAG greedy leaves it in a solo round and the gated dispatcher
drains all units around it, so reordering alone cannot hide the
memory-bound decode work queued next to it.  This benchmark measures
what Kernelet-style slicing (:mod:`repro.slice`) buys on exactly those
workloads: prefill-heavy continuous-batching mixes on mixtral 8x7b and
deepseek-v2 traced to per-layer chains, where every prefill stage is
oversized (8192/6144 tokens against the 4096-slot round budget).

Each workload x slice policy is evaluated on the single-core serving
device AND on a 4-core serving slice
(``make_serving_device(n_units=4)``, rows suffixed ``@x4``), where
slices genuinely co-execute across cores and the slicing gain
multiplies.

Per workload, policy and device:

* gated makespan (``DagEventSimulator``) of the unsliced constrained
  greedy (``greedy_order_dag``) — the PR 3 baseline,
* gated makespan of the lazy sliced greedy
  (``greedy_order_slices``) and of its precedence-respecting **gated**
  refinement (``refine_order_slices(model="gated")`` — the local
  search optimizes the gated DAG makespan directly via
  ``repro.graph.delta.GatedDeltaEvaluator``, so the refined time is
  the schedule's own scoring currency, no greedy fallback),
* the sliced greedy's percentile rank among >= 200 random topological
  orders of the *sliced* graph (uniform-tie-break Kahn sampling) —
  the paper's Fig. 1 design-space protocol.

The ISSUE-4 acceptance bar: sliced greedy strictly below the unsliced
makespan on >= 2 workloads, at >= the 90th percentile of the sampled
design space (single-core rows, as committed).  The ISSUE-5 bar:
gated refinement strictly below the sliced greedy on the @x4 rows.
Slice factor 1 degeneracy (policy=None reproducing the unsliced
pipeline bit-for-bit) is pinned separately in ``tests/test_slice.py``.

Emits ``BENCH_slicing.json``.  Run:
  PYTHONPATH=src python benchmarks/slicing.py
"""

from __future__ import annotations

import argparse
import json
import time

from repro.configs import get_config
from repro.core import percentile_rank
from repro.core.tpu import make_serving_device
from repro.graph import DagEventSimulator, greedy_order_dag, trace_arch
from repro.slice import SlicePolicy, greedy_order_slices, refine_order_slices

__all__ = ["run", "WORKLOADS"]

N_RANDOM = 200

#: prefill-heavy continuous-batching snapshots whose prefill stages
#: are oversized against the 4096-slot round budget, with a decode
#: backlog supplying the memory-bound work slicing lets co-execute.
WORKLOADS = {
    "mixtral-8x7b-prefill": (
        "mixtral-8x7b",
        [("prefill", 8192), ("prefill", 6144)] +
        [("decode", 2048 + 3072 * i) for i in range(16)]),
    "deepseek-v2-prefill": (
        "deepseek-v2-236b",
        [("prefill", 6144), ("prefill", 8192)] +
        [("decode", 2048 + 4096 * i) for i in range(20)]),
}

POLICIES = {
    "occupancy": SlicePolicy(),
    "round_fill": SlicePolicy(mode="round_fill"),
}


def _evaluate(name: str, arch: str, reqs, device, *, policy_name: str,
              policy: SlicePolicy, n_random: int, seed: int,
              refine_budget: int) -> dict:
    traced = trace_arch(get_config(arch, "full"), reqs, max_stages=8)
    g = traced.graph
    g.validate()
    un = greedy_order_dag(g.kernels, device, edges=g.edges)
    t_un = DagEventSimulator(device, g.edges_by_id()).simulate(un.order)
    t0 = time.perf_counter()
    sl = greedy_order_slices(g.kernels, device, edges=g.edges,
                             policy=policy)
    wall = time.perf_counter() - t0
    sg = sl.graph()
    sg.validate()
    assert sg.is_topological(sl.order)
    sim = DagEventSimulator(device, sl.edges_by_id())
    t_sl = sim.simulate(sl.order)
    # Gated refinement: the hill-climb's objective IS the gated
    # makespan of the sliced DAG (slice/join edges in the legality
    # filter, zero-work joins retired instantly), so t_ref is the true
    # gated time of the refined order — never worse than the greedy.
    order, t_ref, refine_evals = refine_order_slices(
        sl, device, budget=refine_budget, model="gated",
        neighborhood="adjacent")
    assert sg.is_topological(order)
    rand = sorted(sim.simulate(o) for o in
                  sg.random_topological_orders(n_random, seed=seed))
    med = rand[len(rand) // 2]
    return {
        "workload": name,
        "arch": arch,
        "device": device.name,
        "slice_policy": policy_name,
        "n_nodes_unsliced": g.n,
        "n_nodes_sliced": len(sl.kernels),
        "n_sliced_stages": len(sl.sliced),
        "slice_passes": sl.passes,
        "construct_wall_s": wall,
        "unsliced_greedy_time_s": t_un,
        "sliced_greedy_time_s": t_sl,
        "sliced_refined_time_s": t_ref,
        "refine_evals": refine_evals,
        "slicing_gain_pct": (t_un / t_sl - 1.0) * 100.0,
        "refined_gain_pct": (t_sl / t_ref - 1.0) * 100.0,
        "refine_beats_greedy": t_ref < t_sl,
        "n_random_orders": n_random,
        "random_median_s": med,
        "random_best_s": rand[0],
        "percentile": percentile_rank(t_sl, rand),
        "refined_percentile": percentile_rank(t_ref, rand),
        "beats_unsliced": t_sl < t_un,
    }


def run(n_random: int = N_RANDOM, seed: int = 1,
        refine_budget: int = 100, print_fn=print) -> dict:
    devices = {"": make_serving_device(),
               "@x4": make_serving_device(n_units=4)}
    results = []
    print_fn("# Kernel slicing on oversized-stage workloads "
             f"({n_random} random topological orders, gated event model, "
             "gated-delta refinement)")
    print_fn("workload,policy,nodes,sliced_nodes,unsliced_ms,sliced_ms,"
             "refined_ms,gain_pct,refine_gain_pct,percentile")
    for name, (arch, reqs) in WORKLOADS.items():
        for pol_name, pol in POLICIES.items():
            for suffix, device in devices.items():
                rec = _evaluate(name + suffix, arch, reqs, device,
                                policy_name=pol_name, policy=pol,
                                n_random=n_random, seed=seed,
                                refine_budget=refine_budget)
                results.append(rec)
                print_fn(
                    f"{rec['workload']},{rec['slice_policy']},"
                    f"{rec['n_nodes_unsliced']},{rec['n_nodes_sliced']},"
                    f"{rec['unsliced_greedy_time_s'] * 1e3:.1f},"
                    f"{rec['sliced_greedy_time_s'] * 1e3:.1f},"
                    f"{rec['sliced_refined_time_s'] * 1e3:.1f},"
                    f"{rec['slicing_gain_pct']:.1f},"
                    f"{rec['refined_gain_pct']:.2f},"
                    f"{rec['percentile']:.1f}")
    # ISSUE-4 acceptance: per single-core workload, the default
    # (occupancy) policy must strictly beat unsliced at >= p90
    default_rows = [r for r in results
                    if r["slice_policy"] == "occupancy"
                    and "@" not in r["workload"]]
    wins = sum(1 for r in default_rows
               if r["beats_unsliced"] and r["percentile"] >= 90.0)
    # ISSUE-5 acceptance: gated refinement strictly beats the sliced
    # greedy on the multi-core (@x4) occupancy rows.
    x4_rows = [r for r in results if r["slice_policy"] == "occupancy"
               and r["workload"].endswith("@x4")]
    refine_wins = sum(1 for r in x4_rows if r["refine_beats_greedy"])
    summary = {
        "workloads_with_strict_win_at_p90": wins,
        "acceptance_ok": wins >= 2,
        "min_gain_pct": min(r["slicing_gain_pct"] for r in default_rows),
        "max_gain_pct": max(r["slicing_gain_pct"] for r in results),
        "refine_strict_wins_x4": refine_wins,
        "refine_acceptance_ok": refine_wins >= 2,
        "max_refined_gain_pct": max(r["refined_gain_pct"]
                                    for r in results),
    }
    print_fn(f"summary: {json.dumps(summary)}")
    return {"benchmark": "slicing", "n_random": n_random, "seed": seed,
            "refine_budget": refine_budget, "refine_model": "gated",
            "results": results, "summary": summary}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_slicing.json")
    ap.add_argument("--n-random", type=int, default=N_RANDOM)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--refine-budget", type=int, default=100)
    args = ap.parse_args(argv)
    out = run(n_random=args.n_random, seed=args.seed,
              refine_budget=args.refine_budget)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
