"""Kernel slicing on oversized-stage workloads: sliced vs unsliced
ready-set greedy under the gated event model (Fig. 1 protocol).

A serving stage whose token footprint exceeds the device's slot budget
(a long prefill chunk against any layer stage) can never share a round
— the DAG greedy leaves it in a solo round and the gated dispatcher
drains all units around it, so reordering alone cannot hide the
memory-bound decode work queued next to it.  This benchmark measures
what Kernelet-style slicing (:mod:`repro.slice`) buys on exactly those
workloads: prefill-heavy continuous-batching mixes on mixtral 8x7b and
deepseek-v2 traced to per-layer chains, where every prefill stage is
oversized (8192/6144 tokens against the 4096-slot round budget).

Per workload and slice policy (occupancy-threshold and
target-round-fill):

* gated makespan (``DagEventSimulator``) of the unsliced constrained
  greedy (``greedy_order_dag``) — the PR 3 baseline,
* gated makespan of the lazy sliced greedy
  (``greedy_order_slices``) and of its precedence-respecting
  refinement (``refine_order_slices``),
* the sliced greedy's percentile rank among >= 200 random topological
  orders of the *sliced* graph (uniform-tie-break Kahn sampling) —
  the paper's Fig. 1 design-space protocol.

The ISSUE-4 acceptance bar: sliced greedy strictly below the unsliced
makespan on >= 2 workloads, at >= the 90th percentile of the sampled
design space.  Slice factor 1 degeneracy (policy=None reproducing the
unsliced pipeline bit-for-bit) is pinned separately in
``tests/test_slice.py``.

Emits ``BENCH_slicing.json``.  Run:
  PYTHONPATH=src python benchmarks/slicing.py
"""

from __future__ import annotations

import argparse
import json
import time

from repro.configs import get_config
from repro.core import percentile_rank
from repro.core.tpu import make_serving_device
from repro.graph import DagEventSimulator, greedy_order_dag, trace_arch
from repro.slice import SlicePolicy, greedy_order_slices, refine_order_slices

__all__ = ["run", "WORKLOADS"]

N_RANDOM = 200

#: prefill-heavy continuous-batching snapshots whose prefill stages
#: are oversized against the 4096-slot round budget, with a decode
#: backlog supplying the memory-bound work slicing lets co-execute.
WORKLOADS = {
    "mixtral-8x7b-prefill": (
        "mixtral-8x7b",
        [("prefill", 8192), ("prefill", 6144)] +
        [("decode", 2048 + 3072 * i) for i in range(16)]),
    "deepseek-v2-prefill": (
        "deepseek-v2-236b",
        [("prefill", 6144), ("prefill", 8192)] +
        [("decode", 2048 + 4096 * i) for i in range(20)]),
}

POLICIES = {
    "occupancy": SlicePolicy(),
    "round_fill": SlicePolicy(mode="round_fill"),
}


def _evaluate(name: str, arch: str, reqs, device, *, policy_name: str,
              policy: SlicePolicy, n_random: int, seed: int,
              refine_budget: int) -> dict:
    traced = trace_arch(get_config(arch, "full"), reqs, max_stages=8)
    g = traced.graph
    g.validate()
    un = greedy_order_dag(g.kernels, device, edges=g.edges)
    t_un = DagEventSimulator(device, g.edges_by_id()).simulate(un.order)
    t0 = time.perf_counter()
    sl = greedy_order_slices(g.kernels, device, edges=g.edges,
                             policy=policy)
    wall = time.perf_counter() - t0
    sg = sl.graph()
    sg.validate()
    assert sg.is_topological(sl.order)
    sim = DagEventSimulator(device, sl.edges_by_id())
    t_sl = sim.simulate(sl.order)
    order, _, _ = refine_order_slices(sl, device, budget=refine_budget,
                                      model="event",
                                      neighborhood="adjacent")
    assert sg.is_topological(order)
    # Refinement optimizes the ungated proxy; under the gated currency
    # the sliced greedy stays the fallback (same convention as
    # benchmarks/dag.py).
    t_ref = min(sim.simulate(order), t_sl)
    rand = sorted(sim.simulate(o) for o in
                  sg.random_topological_orders(n_random, seed=seed))
    med = rand[len(rand) // 2]
    return {
        "workload": name,
        "arch": arch,
        "slice_policy": policy_name,
        "n_nodes_unsliced": g.n,
        "n_nodes_sliced": len(sl.kernels),
        "n_sliced_stages": len(sl.sliced),
        "slice_passes": sl.passes,
        "construct_wall_s": wall,
        "unsliced_greedy_time_s": t_un,
        "sliced_greedy_time_s": t_sl,
        "sliced_refined_time_s": t_ref,
        "slicing_gain_pct": (t_un / t_sl - 1.0) * 100.0,
        "n_random_orders": n_random,
        "random_median_s": med,
        "random_best_s": rand[0],
        "percentile": percentile_rank(t_sl, rand),
        "beats_unsliced": t_sl < t_un,
    }


def run(n_random: int = N_RANDOM, seed: int = 1,
        refine_budget: int = 40, print_fn=print) -> dict:
    device = make_serving_device()
    results = []
    print_fn("# Kernel slicing on oversized-stage workloads "
             f"({n_random} random topological orders, gated event model)")
    print_fn("workload,policy,nodes,sliced_nodes,unsliced_ms,sliced_ms,"
             "refined_ms,gain_pct,percentile")
    for name, (arch, reqs) in WORKLOADS.items():
        for pol_name, pol in POLICIES.items():
            rec = _evaluate(name, arch, reqs, device,
                            policy_name=pol_name, policy=pol,
                            n_random=n_random, seed=seed,
                            refine_budget=refine_budget)
            results.append(rec)
            print_fn(f"{rec['workload']},{rec['slice_policy']},"
                     f"{rec['n_nodes_unsliced']},{rec['n_nodes_sliced']},"
                     f"{rec['unsliced_greedy_time_s'] * 1e3:.1f},"
                     f"{rec['sliced_greedy_time_s'] * 1e3:.1f},"
                     f"{rec['sliced_refined_time_s'] * 1e3:.1f},"
                     f"{rec['slicing_gain_pct']:.1f},"
                     f"{rec['percentile']:.1f}")
    # acceptance: per workload, the default (occupancy) policy must
    # strictly beat unsliced at >= the 90th percentile
    default_rows = [r for r in results if r["slice_policy"] == "occupancy"]
    wins = sum(1 for r in default_rows
               if r["beats_unsliced"] and r["percentile"] >= 90.0)
    summary = {
        "workloads_with_strict_win_at_p90": wins,
        "acceptance_ok": wins >= 2,
        "min_gain_pct": min(r["slicing_gain_pct"] for r in default_rows),
        "max_gain_pct": max(r["slicing_gain_pct"] for r in results),
    }
    print_fn(f"summary: {json.dumps(summary)}")
    return {"benchmark": "slicing", "n_random": n_random, "seed": seed,
            "refine_budget": refine_budget, "results": results,
            "summary": summary}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_slicing.json")
    ap.add_argument("--n-random", type=int, default=N_RANDOM)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)
    out = run(n_random=args.n_random, seed=args.seed)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
