"""Paper Fig. 1: ranking + distribution over the FULL 8! = 40,320
permutation space of EpBsEsSw-8.

Reports the algorithm's percentile, the median-vs-algorithm gain (the
paper: >=16.1% for 50% of random choices) and a 10-bin histogram of the
design space."""

from __future__ import annotations

import itertools

import numpy as np

from repro.core import (GTX580, EXPERIMENTS, greedy_order_fast,
                        percentile_rank, simulate)
from repro.core.refine import refined_schedule

__all__ = ["run"]


def run(print_fn=print) -> dict:
    ks = EXPERIMENTS["EpBsEsSw-8"]()
    sched = greedy_order_fast(ks, GTX580)
    t_alg = simulate(sched.order, GTX580)
    _, t_ref = refined_schedule(ks, GTX580)
    times = np.array([simulate([ks[i] for i in p], GTX580)
                      for p in itertools.permutations(range(len(ks)))])
    med = float(np.median(times))
    out = {
        "n_permutations": len(times),
        "algorithm_ms": t_alg * 1e3,
        "refined_ms": t_ref * 1e3,
        "optimal_ms": float(times.min()) * 1e3,
        "worst_ms": float(times.max()) * 1e3,
        "median_ms": med * 1e3,
        "percentile": percentile_rank(t_alg, times),
        "refined_percentile": percentile_rank(t_ref, times),
        "median_gain_pct": (med / t_alg - 1) * 100,
        "speedup_over_worst": float(times.max()) / t_alg,
    }
    print_fn("# Fig 1: EpBsEsSw-8 full permutation space")
    for k, v in out.items():
        print_fn(f"{k},{v:.2f}" if isinstance(v, float) else f"{k},{v}")
    hist, edges = np.histogram(times * 1e3, bins=10)
    print_fn("histogram_ms_bin,count")
    for h, e0, e1 in zip(hist, edges[:-1], edges[1:]):
        print_fn(f"{e0:.1f}-{e1:.1f},{h}")
    return out
