"""CI guard: fail on schedule-construction wall-time regressions.

Re-runs the ``benchmarks/scaling.py`` fast-path construction cells on
this machine and diffs them against the committed
``BENCH_scheduler_scaling.json``: any (scenario, n) whose fresh
``path="fast"`` wall time exceeds the committed one by more than
``--threshold`` (default 1.25x, plus ``--abs-slack`` seconds so
second-scale cells don't flap on scheduler/runner jitter — on a
shared single-core box the sub-second refine cells swing several
hundred ms run-to-run, so the absolute slack, not the ratio, is what
keeps them stable; a genuinely devectorized batched path is caught by
the load-insensitive ``--batched-floor`` throughput ratio instead)
fails the check with exit code 1.  The event-refine delta cells are compared the same
way (their wall time is the event-model refinement hot path).  Both
sides use best-of-``--repeats`` wall times (the committed JSON records
its own ``repeats``), the standard protocol for wall-clock guards.

Two absolute floors ride along (both load-insensitive ratios of two
fresh runs on the same box, so they need no committed baseline): the
batched-vs-sequential refine throughput ratio (``--batched-floor``)
and, since PR 7, the incremental-vs-batch compose-time speedup under
serving churn (``--churn-floor``, re-running
``benchmarks/serving.py``'s ``churn_compose_bench`` at its largest
``n_live`` cell).  The ``repro.serve`` re-export surface is also
import-checked, so the PR 7 package split can't silently drop the
historical flat names.

This is a same-machine tool: committed numbers are only comparable to
runs on comparable hardware, so the intended use is "run the benchmark
before and after a change on one box" (or a pinned CI runner), not
cross-machine comparison.

Run:  PYTHONPATH=src python benchmarks/check_regression.py
      PYTHONPATH=src python benchmarks/check_regression.py --quick
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import scaling  # noqa: E402

#: cells whose wall time is a guarded hot path (``dag_fast`` is the
#: ready-set constrained greedy, repro.graph.greedy_order_dag;
#: ``slice_fast`` the lazy slice-aware greedy,
#: repro.slice.greedy_order_slices; ``dag_refine_gated`` the gated
#: delta-refinement path, repro.graph.delta.GatedDeltaEvaluator via
#: refine_order_dag(model="gated"); ``event_batched`` /
#: ``dag_refine_gated_batched`` the vectorized candidate evaluator,
#: repro.core.batched.refine_order_batched)
_GUARDED_PATHS = ("fast", "event_delta", "dag_fast", "slice_fast",
                  "dag_refine_gated", "event_batched",
                  "dag_refine_gated_batched")

#: floor on the fresh run's batched-vs-sequential effective-move
#: throughput ratio at n >= 512 (the committed JSON records >= 3x;
#: the guard is deliberately looser so shared-runner noise doesn't
#: flap it, while still catching a devectorized batched path)
_BATCHED_FLOOR = 2.0

#: floor on the fresh incremental-vs-batch compose-time speedup at the
#: churn benchmark's largest n_live cell (benchmarks/serving.py,
#: ``churn_compose_bench``; the committed BENCH_serving.json records
#: >= 2x at 64 live requests — same looser-than-committed discipline
#: as the batched floor, catching a live path that degenerated into
#: rebuild-every-step without flapping on runner noise)
_CHURN_FLOOR = 1.6

#: ceiling on the tracing-enabled / tracing-disabled wall-time ratio
#: of a compose + gated-simulate pass (PR 8: every instrumentation
#: site is a ``trace is not None`` guard plus a list append, so a
#: live :class:`repro.obs.ScheduleTrace` must stay within 10% of the
#: null recorder — a hot-path emission that got expensive shows up
#: here before it shows up in serving step times)
_TRACE_OVERHEAD = 1.10

#: ceiling on the audit-on / audit-off wall-time ratio of the serving
#: compose loop at ``audit_frac=0.05`` (PR 9: the online Fig.-1 audit
#: re-scores one served step in twenty against 50 delta-evaluated
#: random orders, so the sampled audits must amortize to within 15%
#: of the audit-off loop — checkpoint reuse in the
#: GatedDeltaEvaluator is what keeps this affordable, and a change
#: that degrades it to K full simulations per audit shows up here)
_AUDIT_OVERHEAD = 1.15

#: ceiling on the frontend / bare-synchronous wall-time ratio over the
#: same request set with every arrival at t=0 (saturation: the queue
#: is never empty, so admission-control costing, routing and the
#: virtual-clock event loop all run on every dispatch).  PR 10's front
#: end is bookkeeping around the same ``engine.step()`` calls, so it
#: must stay within 15% of the bare loop — an admission scan that went
#: quadratic-expensive or a per-dispatch recompose shows up here.
_FRONTEND_OVERHEAD = 1.15

#: the PR 7 package split re-exports the historical flat import
#: surface; a rename that silently drops one of these breaks every
#: external consumer, so the guard imports them by name
_SERVE_SURFACE = ("Request", "ScheduleCache", "SchedulerPolicy",
                  "ServingEngine", "Signature")

#: PR 10 async-serving surface, same discipline per module
_FRONTEND_SURFACE = {
    "repro.serve": ("ServingFrontend", "AdmissionPolicy",
                    "VirtualClock", "LoadGenerator", "make_workload"),
    "repro.serve.frontend": ("ServingFrontend", "AdmissionPolicy",
                             "VirtualClock"),
    "repro.serve.loadgen": ("LoadGenerator", "make_workload",
                            "poisson_arrivals", "bursty_arrivals",
                            "diurnal_arrivals"),
}


def trace_overhead_ratio(*, repeats: int = 7, inner: int | None = None,
                         min_sample_s: float = 0.05) -> dict:
    """Wall-time ratio of a traced vs untraced compose + simulate
    pass: the ready-set greedy over a traced qwen arch on the x4
    serving device, then :class:`repro.graph.streams.DagEventSimulator`
    with a live :class:`repro.obs.ScheduleTrace` vs ``trace=None``.

    Interleaved best-of-``repeats`` (each repeat times both sides
    back-to-back) so slow drift on a shared runner hits both sides
    equally.  ``inner`` (passes per timed sample) defaults to
    whatever makes one untraced sample take at least
    ``min_sample_s`` — a single compose+simulate pass is sub-ms, and
    a ratio of two sub-ms samples flaps on any scheduler hiccup, so
    the sample is stretched until the 10% headroom is milliseconds
    wide and best-of-k can actually filter the noise."""
    import time

    from repro.configs import get_config
    from repro.core.tpu import make_serving_device
    from repro.graph.constrained import greedy_order_dag
    from repro.graph.kernel_graph import trace_arch
    from repro.graph.streams import DagEventSimulator
    from repro.obs import ScheduleTrace

    cfg = get_config("qwen1.5-0.5b", "full")
    traced = trace_arch(cfg, [("prefill", 64)] * 3
                        + [("decode", 128)] * 3, max_stages=48)
    g = traced.graph
    device = make_serving_device(n_units=4)
    eids = g.edges_by_id()

    def once(with_trace: bool, n: int = 1) -> float:
        t0 = time.perf_counter()
        for _ in range(n):
            sched = greedy_order_dag(g.kernels, device, edges=g.edges)
            tr = ScheduleTrace() if with_trace else None
            DagEventSimulator(device, eids).simulate(sched.order,
                                                     trace=tr)
        return time.perf_counter() - t0

    warm = once(False)                # warm caches on neither side
    if inner is None:
        # calibrate: stretch the sample until one untraced timing is
        # at least min_sample_s, so the gate compares multi-ms walls
        inner = max(1, int(math.ceil(min_sample_s / max(warm, 1e-6))))
    t_off = t_on = float("inf")
    for _ in range(max(repeats, 1)):
        t_off = min(t_off, once(False, inner))
        t_on = min(t_on, once(True, inner))
    return {"wall_off_s": t_off, "wall_on_s": t_on, "inner": inner,
            "ratio": t_on / max(t_off, 1e-12)}


def audit_overhead_ratio(*, repeats: int = 7, inner: int | None = None,
                         min_sample_s: float = 0.05,
                         frac: float = 0.05, k: int = 50) -> dict:
    """Wall-time ratio of the serving compose loop with the online
    quality audit sampling at ``frac`` vs auditing disabled.

    Model-free replica of the engine's step hook: each pass composes a
    traced qwen step cold (``kind="refined"``, gated refinement and
    guard) and, on the auditor's deterministic ``frac`` sample, scores
    it against ``k`` random topological orders through
    :class:`repro.obs.QualityAuditor` — exactly what
    ``audit_frac=0.05`` costs a serving engine.  Interleaved
    best-of-``repeats`` like :func:`trace_overhead_ratio`, with one
    twist: the timed sample is stretched to a multiple of the sampling
    period ``1/frac`` so every sample pays the same whole number of
    audits (a fractional period would make the ratio depend on where
    the sample window cuts the deterministic audit pattern)."""
    import time

    import numpy as np

    from repro.configs import get_config
    from repro.core.tpu import make_serving_device
    from repro.graph.kernel_graph import (arch_kv_bytes_per_token,
                                          estimate_n_params)
    from repro.serve import (Composer, Request, ScheduleCache,
                             SchedulerPolicy, build_dag_triples)

    cfg = get_config("qwen1.5-0.5b", "full")
    n_params = estimate_n_params(cfg)
    kvb = arch_kv_bytes_per_token(cfg)
    decoded = object()   # build_dag_triples only checks `cache is None`
    reqs = []
    for rid, (phase, n) in enumerate([("prefill", 64)] * 2
                                     + [("decode", 128 * (i + 1))
                                        for i in range(3)]):
        r = Request(rid, np.zeros(n, np.int32))
        if phase == "decode":
            r.cache, r.pos = decoded, n
        reqs.append(r)
    # small step graph: one timed sample is 1/frac composes, so the
    # per-step cost sets the gate's total wall time
    triples, traced = build_dag_triples(cfg, reqs, n_params=n_params,
                                        kv_bytes_per_token=kvb,
                                        max_stages=8)
    device = make_serving_device(n_units=4)

    def once(f: float, n: int = 1) -> float:
        # fresh composer per sample: the auditor's step counter
        # restarts, so every audit-on sample fires the identical
        # deterministic audit pattern
        pol = SchedulerPolicy(kind="refined", respect_deps=True,
                              refine_model="gated", dag_guard="gated",
                              cache=False, audit_frac=f, audit_k=k)
        comp = Composer(pol, device, 2.0 * n_params, ScheduleCache())
        aud = comp.auditor
        t0 = time.perf_counter()
        for _ in range(n):
            rounds = comp.compose_dag(triples, traced)
            if aud.sample_step():
                aud.audit_dag(rounds, traced, arch=cfg.name,
                              kind="refined")
        return time.perf_counter() - t0

    warm = once(0.0)                  # warm caches on neither side
    period = max(1, round(1.0 / frac))
    if inner is None:
        inner = max(1, int(math.ceil(min_sample_s / max(warm, 1e-6))))
    inner = period * int(math.ceil(inner / period))
    t_off = t_on = float("inf")
    for _ in range(max(repeats, 1)):
        t_off = min(t_off, once(0.0, inner))
        t_on = min(t_on, once(frac, inner))
    return {"wall_off_s": t_off, "wall_on_s": t_on, "inner": inner,
            "audit_frac": frac, "audit_k": k,
            "audits_per_sample": inner // period,
            "ratio": t_on / max(t_off, 1e-12)}


def frontend_overhead_ratio(*, repeats: int = 7,
                            inner: int | None = None,
                            min_sample_s: float = 0.05,
                            n_requests: int = 6) -> dict:
    """Wall-time ratio of the async front end vs the bare synchronous
    ``ServingEngine`` loop over the *same request set* at saturation
    (every arrival at virtual t=0, so the arrival queue is never empty
    and admission costing + routing + the event loop run on every
    dispatch).

    Engines are built and jit-warmed *outside* the timed region (a
    fresh engine recompiles its decode step; both sides would pay it,
    but it would drown the bookkeeping delta this gate exists to
    bound).  Interleaved best-of-``repeats`` with the sample stretched
    to at least ``min_sample_s`` like the other overhead gates."""
    import time

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.serve import (AdmissionPolicy, Request, SchedulerPolicy,
                             ServingEngine, ServingFrontend)

    cfg = get_config("qwen1.5-0.5b", "smoke")
    params = T.init(jax.random.PRNGKey(0), cfg)

    def mk_engine() -> ServingEngine:
        eng = ServingEngine(cfg, params, max_len=32,
                            policy=SchedulerPolicy())
        # jit-warm with a throwaway request (compile out of the timing)
        eng.submit([Request(-1, np.zeros(2, np.int32),
                            max_new_tokens=1)])
        eng.run()
        return eng

    def mk_reqs() -> list[Request]:
        rng = np.random.default_rng(0)
        return [Request(i, rng.integers(0, 128, size=4).astype(np.int32),
                        max_new_tokens=4) for i in range(n_requests)]

    def once(front: bool, n: int = 1) -> float:
        engines = [mk_engine() for _ in range(n)]
        batches = [mk_reqs() for _ in range(n)]
        t0 = time.perf_counter()
        for eng, batch in zip(engines, batches):
            if front:
                fe = ServingFrontend(
                    [eng], AdmissionPolicy(round_cost_budget_s=1.0))
                fe.run([(0.0, r) for r in batch])
            else:
                eng.submit(batch)
                eng.run()
        return time.perf_counter() - t0

    warm = once(False)                # warm caches on neither side
    if inner is None:
        inner = max(1, int(math.ceil(min_sample_s / max(warm, 1e-6))))
    t_off = t_on = float("inf")
    for _ in range(max(repeats, 1)):
        t_off = min(t_off, once(False, inner))
        t_on = min(t_on, once(True, inner))
    return {"wall_off_s": t_off, "wall_on_s": t_on, "inner": inner,
            "n_requests": n_requests,
            "ratio": t_on / max(t_off, 1e-12)}


def _surface_regressions() -> list[str]:
    out = []
    surfaces = {"repro.serve": _SERVE_SURFACE,
                "repro.serve.engine": _SERVE_SURFACE}
    for mod, names in list(surfaces.items()) + \
            list(_FRONTEND_SURFACE.items()):
        try:
            m = __import__(mod, fromlist=list(names))
        except ImportError as e:
            out.append(f"import surface: {mod} failed to import ({e})")
            continue
        for name in names:
            if not hasattr(m, name):
                out.append(f"import surface: {mod}.{name} is gone")
    return out


def compare(committed: dict, fresh: dict, threshold: float,
            abs_slack: float = 0.75) -> list[str]:
    """Regression messages for every guarded cell above threshold."""
    old = {(r["scenario"], r["n"], r["path"]): r["wall_s"]
           for r in committed.get("results", [])
           if r["path"] in _GUARDED_PATHS}
    regressions = []
    for r in fresh.get("results", []):
        key = (r["scenario"], r["n"], r["path"])
        if r["path"] not in _GUARDED_PATHS or key not in old:
            continue
        base = old[key]
        if base > 0 and r["wall_s"] > base * threshold + abs_slack:
            regressions.append(
                f"{key[0]}@n={key[1]}[{key[2]}]: "
                f"{r['wall_s']:.3f}s vs committed {base:.3f}s "
                f"({r['wall_s'] / base:.2f}x > {threshold:.2f}x)")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "BENCH_scheduler_scaling.json"),
        help="committed benchmark JSON to diff against")
    ap.add_argument("--threshold", type=float, default=1.25)
    ap.add_argument("--abs-slack", type=float, default=0.75,
                    help="absolute seconds of slack on top of the "
                         "ratio threshold (runner-jitter floor: "
                         "sub-second refine cells swing hundreds of "
                         "ms on a shared single-core runner)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="best-of-k for the fresh run (default: the "
                         "committed JSON's own repeats)")
    ap.add_argument("--batched-floor", type=float,
                    default=_BATCHED_FLOOR,
                    help="minimum batched/sequential effective-move "
                         "throughput ratio at n >= 512 (0 disables)")
    ap.add_argument("--churn-floor", type=float, default=_CHURN_FLOOR,
                    help="minimum incremental/batch compose-time "
                         "speedup at the churn benchmark's largest "
                         "n_live cell (0 disables; re-runs "
                         "benchmarks/serving.py churn_compose_bench "
                         "fresh)")
    ap.add_argument("--trace-overhead", type=float,
                    default=_TRACE_OVERHEAD,
                    help="ceiling on the traced/untraced wall-time "
                         "ratio of a compose + gated-simulate pass "
                         "(0 disables; interleaved best-of-k on this "
                         "box, no committed baseline needed)")
    ap.add_argument("--audit-overhead", type=float,
                    default=_AUDIT_OVERHEAD,
                    help="ceiling on the audit-on/audit-off wall-time "
                         "ratio of the serving compose loop at "
                         "audit_frac=0.05 (0 disables; interleaved "
                         "best-of-k on this box, no committed "
                         "baseline needed)")
    ap.add_argument("--frontend-overhead", type=float,
                    default=_FRONTEND_OVERHEAD,
                    help="ceiling on the async-frontend/bare-engine "
                         "wall-time ratio over the same request set "
                         "at saturation arrival rate (0 disables; "
                         "interleaved best-of-k on this box, no "
                         "committed baseline needed)")
    ap.add_argument("--quick", action="store_true",
                    help="skip the slow oracle/full baselines entirely "
                         "(fresh run measures only the guarded cells)")
    ap.add_argument("--out", default=None,
                    help="optionally write the fresh run's JSON here")
    args = ap.parse_args(argv)

    with open(args.bench) as f:
        committed = json.load(f)
    # The guarded cells are the fast/delta paths; the reference oracle
    # and full-re-sim baselines only provide speedup context, so the
    # fresh run can skip them (--quick) without losing coverage.
    max_ref = 0 if args.quick else committed.get("max_ref_n", 512)
    max_event_full = (0 if args.quick
                      else committed.get("max_event_full_n", 256))
    max_gated_full = (0 if args.quick
                      else committed.get("max_gated_full_n", 128))
    repeats = (args.repeats if args.repeats is not None
               else committed.get("repeats", 2))
    fresh = scaling.run(max_ref_n=max_ref,
                        max_event_full_n=max_event_full,
                        max_gated_full_n=max_gated_full,
                        repeats=repeats)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(fresh, f, indent=2)
    regressions = compare(committed, fresh, args.threshold,
                          args.abs_slack)
    if args.batched_floor > 0:
        ratio = fresh["summary"].get("min_batched_event_ratio_at_512plus")
        if ratio is not None and ratio < args.batched_floor:
            regressions.append(
                f"batched event-refine throughput ratio at n>=512: "
                f"{ratio:.2f}x < floor {args.batched_floor:.2f}x")
    regressions += _surface_regressions()
    if args.churn_floor > 0:
        import serving
        rows = serving.churn_compose_bench(print_fn=lambda *_: None)
        top = max(rows, key=lambda r: r["n_live"])
        if top["compose_speedup"] < args.churn_floor:
            regressions.append(
                f"incremental compose speedup under churn at "
                f"n_live={top['n_live']}: "
                f"{top['compose_speedup']:.2f}x < floor "
                f"{args.churn_floor:.2f}x")
    if args.trace_overhead > 0:
        tr = trace_overhead_ratio()
        if tr["ratio"] > args.trace_overhead:
            regressions.append(
                f"schedule-trace overhead: traced compose+simulate "
                f"{tr['ratio']:.3f}x untraced "
                f"({tr['wall_on_s'] * 1e3:.1f} ms vs "
                f"{tr['wall_off_s'] * 1e3:.1f} ms) > ceiling "
                f"{args.trace_overhead:.2f}x")
    if args.audit_overhead > 0:
        au = audit_overhead_ratio()
        if au["ratio"] > args.audit_overhead:
            regressions.append(
                f"online-audit overhead: audit_frac={au['audit_frac']} "
                f"compose loop {au['ratio']:.3f}x audit-off "
                f"({au['wall_on_s'] * 1e3:.1f} ms vs "
                f"{au['wall_off_s'] * 1e3:.1f} ms, "
                f"{au['audits_per_sample']} audits/sample) > ceiling "
                f"{args.audit_overhead:.2f}x")
    if args.frontend_overhead > 0:
        fr = frontend_overhead_ratio()
        if fr["ratio"] > args.frontend_overhead:
            regressions.append(
                f"async-frontend overhead: saturated dispatch loop "
                f"{fr['ratio']:.3f}x the bare synchronous engine "
                f"({fr['wall_on_s'] * 1e3:.1f} ms vs "
                f"{fr['wall_off_s'] * 1e3:.1f} ms over "
                f"{fr['n_requests']} requests) > ceiling "
                f"{args.frontend_overhead:.2f}x")
    if regressions:
        print("\nREGRESSION: construction wall time exceeded "
              f"{args.threshold:.2f}x the committed baseline:")
        for msg in regressions:
            print(f"  {msg}")
        return 1
    n_cells = sum(1 for r in fresh["results"]
                  if r["path"] in _GUARDED_PATHS)
    print(f"\nok: {n_cells} guarded cells within "
          f"{args.threshold:.2f}x of committed baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
