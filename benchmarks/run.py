"""Benchmark harness — one section per paper table/figure + the TPU
adaptation studies.  Prints CSV sections; also usable as
``python -m benchmarks.run``."""

from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    from benchmarks import dag, fig1, roofline, serving, slicing, table3
    table3.run()
    print()
    fig1.run()
    print()
    serving.run()
    print()
    dag.run()
    print()
    slicing.run()
    print()
    roofline.run()
    print(f"\n# total benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
