"""DAG workloads: constrained greedy vs random topological launch
orders (the paper's Fig. 1 protocol generalized to dependency graphs).

The paper evaluates Algorithm 1 by ranking its launch order inside the
full permutation space of an independent kernel batch.  On a kernel
DAG the design space is the set of *topological* orders, so this
benchmark ranks ``repro.graph.greedy_order_dag`` against >= 200 random
topological orders (uniform-tie-break Kahn sampling) under the gated
event model (``DagEventSimulator`` — dependent kernels never overlap),
for

* traced architecture workloads (``trace_arch`` over full model
  configs: per-layer chains of a continuous-batching snapshot), on the
  single-core serving device AND on a 4-core serving slice
  (``make_serving_device(n_units=4)``, rows suffixed ``@x4``), and
* a synthetic layered GPU-kernel DAG on the paper's GTX 580 model.

Refinement rows use ``refine_order_dag(model="gated")`` — the
precedence-respecting local search delta-evaluated *in the gated
currency itself* (``repro.graph.delta.GatedDeltaEvaluator``), so the
reported refined time IS the gated makespan of the refined order, no
greedy fallback involved.  On the single-core device the ready-set
greedy's aligned rounds are a local optimum of the swap/reinsert
neighbourhood (all cohorts admitted at one instant finish together —
measured: zero improving legal moves on all three archs), so the
refined rows match the greedy there; the ``@x4`` multi-core rows are
where placement and under-occupancy make the gated makespan genuinely
order-sensitive and refinement strictly beats the greedy (the ISSUE-5
acceptance bar: strict refined-vs-greedy wins on >= 2 traced archs).

Reported per workload: modelled gated makespan of the constrained
greedy and of the gated refinement, percentile ranks inside the
sampled design space, and the median-vs-greedy gain.  The ISSUE-3
acceptance bar (greedy beats the sample median on >= 2 traced arch
workloads) is retained.

Emits ``BENCH_dag.json``.  Run:
  PYTHONPATH=src python benchmarks/dag.py
"""

from __future__ import annotations

import argparse
import json
import random
import time

from repro.configs import get_config
from repro.core import GTX580, percentile_rank
from repro.core.resources import bs_kernel, ep_kernel, es_kernel, sw_kernel
from repro.core.tpu import make_serving_device
from repro.graph import (DagEventSimulator, KernelGraph, greedy_order_dag,
                         refine_order_dag, trace_arch)

__all__ = ["run", "layered_gpu_dag"]

N_RANDOM = 200
_FAMS = [ep_kernel, bs_kernel, es_kernel, sw_kernel]

#: traced arch workloads (full configs, coarsened to 16 stages per
#: request so the 200-order sweep stays fast)
ARCH_WORKLOADS = ("qwen1.5-0.5b", "mixtral-8x7b", "deepseek-v2-236b")


def layered_gpu_dag(rng: random.Random, n: int,
                    width: int = 16) -> KernelGraph:
    """A layered synthetic DAG: ``width`` parallel chains of mixed
    GTX580 kernels with occasional cross-chain edges — the irregular
    precedence structure ACS-style workloads exhibit."""
    ks = [rng.choice(_FAMS)(f"k{i}",
                            grid=rng.choice([8, 16, 32, 48, 64, 96]),
                            shm=rng.choice([0, 4096, 8192, 16384, 24576]),
                            inst=rng.uniform(1e6, 5e8))
          for i in range(n)]
    edges = set()
    chains: list[list[int]] = [[] for _ in range(width)]
    for i in range(n):
        c = chains[rng.randrange(width)]
        if c:
            edges.add((c[-1], i))
        c.append(i)
        # sparse cross-chain joins (always older -> newer: acyclic)
        if i > width and rng.random() < 0.15:
            j = rng.randrange(i - width)
            edges.add((j, i))
    return KernelGraph(ks, edges)


def _evaluate(name: str, graph: KernelGraph, device, *,
              n_random: int, seed: int, refine_budget: int) -> dict:
    graph.validate()
    eids = graph.edges_by_id()
    sim = DagEventSimulator(device, eids)
    t0 = time.perf_counter()
    sched = greedy_order_dag(graph.kernels, device, edges=graph.edges)
    wall = time.perf_counter() - t0
    assert graph.is_topological(sched.order)
    t_alg = sim.simulate(sched.order)
    # Gated refinement: the hill-climb's objective IS the gated
    # makespan (delta-evaluated suffix re-simulation), so t_ref is the
    # true gated time of the refined order — never worse than greedy.
    t0 = time.perf_counter()
    order, t_ref, refine_evals = refine_order_dag(
        sched.order, device, edge_ids=eids, budget=refine_budget,
        model="gated", neighborhood="adjacent")
    refine_wall = time.perf_counter() - t0
    assert graph.is_topological(order)
    assert abs(t_ref - sim.simulate(order)) <= 1e-12 * max(t_ref, 1.0)
    rand = sorted(sim.simulate(o) for o in
                  graph.random_topological_orders(n_random, seed=seed))
    med = rand[len(rand) // 2]
    return {
        "workload": name,
        "device": device.name,
        "n_nodes": graph.n,
        "n_edges": len(graph.edges),
        "rounds": len(sched.rounds),
        "construct_wall_s": wall,
        "refine_wall_s": refine_wall,
        "refine_evals": refine_evals,
        "greedy_time_s": t_alg,
        "refined_time_s": t_ref,
        "refined_gain_pct": (t_alg / t_ref - 1.0) * 100.0,
        "refine_beats_greedy": t_ref < t_alg,
        "n_random_orders": n_random,
        "random_median_s": med,
        "random_best_s": rand[0],
        "random_worst_s": rand[-1],
        "percentile": percentile_rank(t_alg, rand),
        "refined_percentile": percentile_rank(t_ref, rand),
        "median_gain_pct": (med / t_alg - 1.0) * 100.0,
        "beats_median": t_alg < med,
    }


def run(n_random: int = N_RANDOM, seed: int = 1,
        refine_budget: int = 200, print_fn=print) -> dict:
    device = make_serving_device()
    slice_dev = make_serving_device(n_units=4)
    results = []
    print_fn("# DAG scheduling vs random topological orders "
             f"({n_random} samples, gated event model, "
             "gated-delta refinement)")
    print_fn("workload,nodes,edges,rounds,greedy_ms,refined_ms,"
             "refine_gain_pct,median_ms,percentile,median_gain_pct")
    for arch in ARCH_WORKLOADS:
        traced = trace_arch(get_config(arch, "full"), max_stages=16)
        rec = _evaluate(f"arch:{arch}", traced.graph, device,
                        n_random=n_random, seed=seed,
                        refine_budget=refine_budget)
        results.append(rec)
        # The multi-core slice rows: placement across cores makes the
        # gated makespan order-sensitive beyond round composition —
        # the regime where gated refinement beats the greedy.
        rec = _evaluate(f"arch:{arch}@x4", traced.graph, slice_dev,
                        n_random=n_random, seed=seed,
                        refine_budget=refine_budget)
        results.append(rec)
    rng = random.Random(seed)
    rec = _evaluate("gpu:layered-64", layered_gpu_dag(rng, 64), GTX580,
                    n_random=n_random, seed=seed,
                    refine_budget=refine_budget)
    results.append(rec)
    for r in results:
        print_fn(f"{r['workload']},{r['n_nodes']},{r['n_edges']},"
                 f"{r['rounds']},{r['greedy_time_s'] * 1e3:.3f},"
                 f"{r['refined_time_s'] * 1e3:.3f},"
                 f"{r['refined_gain_pct']:.2f},"
                 f"{r['random_median_s'] * 1e3:.3f},"
                 f"{r['percentile']:.1f},{r['median_gain_pct']:.1f}")
    arch_beats = sum(1 for r in results
                     if r["workload"].startswith("arch:")
                     and "@" not in r["workload"] and r["beats_median"])
    refine_wins = sum(1 for r in results
                      if r["workload"].endswith("@x4")
                      and r["refine_beats_greedy"])
    summary = {
        "arch_workloads_beating_median": arch_beats,
        "acceptance_ok": arch_beats >= 2,
        "min_percentile": min(r["percentile"] for r in results),
        # ISSUE-5 acceptance: gated refinement strictly beats greedy
        # (gated makespan) on >= 2 of the 3 traced archs (@x4 rows).
        "arch_refine_strict_wins_x4": refine_wins,
        "refine_acceptance_ok": refine_wins >= 2,
        "max_refined_gain_pct": max(r["refined_gain_pct"]
                                    for r in results),
    }
    print_fn(f"summary: {json.dumps(summary)}")
    return {"benchmark": "dag_scheduling", "n_random": n_random,
            "seed": seed, "refine_budget": refine_budget,
            "refine_model": "gated",
            "results": results, "summary": summary}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_dag.json")
    ap.add_argument("--n-random", type=int, default=N_RANDOM)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--refine-budget", type=int, default=200)
    args = ap.parse_args(argv)
    out = run(n_random=args.n_random, seed=args.seed,
              refine_budget=args.refine_budget)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
